"""The FDR-analogue assertions of paper Listing 3 (lines 53-58), exhaustively
checked on the composed LTS, plus the generalisations the deployed network
actually uses (W workers per node) and the erratum exhibit."""

import pytest

from repro.core.verify import verify_network


@pytest.mark.parametrize(
    "n,w,m",
    [
        (1, 1, 5),
        (2, 1, 5),  # the paper's exact finitisation: N=2, 5 objects
        (2, 2, 4),
        (3, 1, 4),
        (3, 2, 3),
    ],
)
def test_network_verifies(n, w, m):
    report = verify_network(n, w, m)
    assert report.deadlock_free, report.summary()
    assert report.divergence_free, report.summary()
    assert report.trace_refines_testsystem, report.summary()
    assert report.failures_refines_testsystem, report.summary()
    assert report.deterministic, report.summary()
    assert report.terminates, report.summary()
    assert report.objects_delivered_exactly_once, report.summary()
    assert report.ok


def test_state_space_is_explored():
    r = verify_network(2, 1, 5)
    # FDR reports thousands of states for this model; ours should too.
    assert r.num_states > 1000
    assert r.num_transitions > r.num_states


def test_literal_paper_model_exhibits_erratum():
    """Listing 3 line 28 as printed: Server_End never terminates (blocks on
    the non-existent channel b.N).  The data path still completes, so the
    failure shows as orderly-termination (not deadlock) in our LTS — in
    CSPm it is a channel type error FDR would reject."""
    r = verify_network(2, 1, 3, literal_paper_model=True)
    assert not r.terminates
    assert not r.ok
    # the corrected model passes
    assert verify_network(2, 1, 3).ok


def test_single_worker_single_object_edge():
    assert verify_network(1, 1, 1).ok
