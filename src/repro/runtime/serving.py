"""Continuous-batching serving engine built on the paper's protocol.

The mapping is direct (DESIGN.md section 2):

    Emit      -> the request queue (`submit`)
    onrl      -> the slot scheduler: it answers an idle slot's *request* with
                 the next queued prompt (demand-driven; the server is never
                 blocked by a busy slot — the paper's liveness invariant)
    nrfa/work -> decode slots: a slot only requests new work after it has
                 delivered its finished sequence (one-place buffer invariant)
    afoc/afo  -> the completion merge
    Collect   -> finished-sequence results (`collect`)
    UT        -> `shutdown()`: drains slots, then the engine terminates

``core.verify`` model-checks this exact network shape; the engine is its
operational twin, as ``runtime.local`` is for batch pipelines.

Decode is *batched across slots* (one jitted ``decode_step`` call per engine
tick, per-slot cache lengths), which is the continuous-batching part: new
requests join on any tick without waiting for others to finish.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.timing import TimingCollector
from repro.models import lm as lm_mod


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16


@dataclass
class Completion:
    rid: int
    tokens: list[int]
    prompt_len: int
    latency_s: float


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 4,
        max_seq: int = 256,
        tp: int = 1,
        rules=None,
        eos_id: int | None = None,
        greedy: bool = True,
    ):
        if cfg.encoder_layers:
            raise NotImplementedError("serving engine targets decoder-only LMs")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.tp = tp
        self.rules = rules
        self.eos_id = eos_id
        self.greedy = greedy
        self.timing = TimingCollector()

        with self.timing.phase("host", "load"):
            self.cache = lm_mod.init_cache(cfg, max_slots, max_seq, tp)
            self.lens = np.zeros(max_slots, np.int32)  # tokens in cache
            self.remaining = np.zeros(max_slots, np.int32)
            self.slot_rid = np.full(max_slots, -1, np.int64)
            self.slot_tokens: list[list[int]] = [[] for _ in range(max_slots)]
            self.slot_prompt_len = np.zeros(max_slots, np.int32)
            self.slot_t0 = np.zeros(max_slots, np.float64)
            self.last_token = np.zeros(max_slots, np.int32)

            self.queue: deque[Request] = deque()  # Emit -> onrl
            self.completions: list[Completion] = []  # Collect
            self._shutdown = False

            self._decode = jax.jit(
                lambda params, cache, tokens, lens: lm_mod.decode_step(
                    cfg, params, cache, tokens, lens, tp=tp, rules=rules
                )
            )

    # -- Emit side -------------------------------------------------------------

    def submit(self, request: Request) -> None:
        if self._shutdown:
            raise RuntimeError("engine is shut down (UT already propagated)")
        self.queue.append(request)

    # -- onrl: answer idle slots' requests with queued work ---------------------

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slot_rid[slot] >= 0 or not self.queue:
                continue  # busy slot never blocks the server
            req = self.queue.popleft()
            prompt = req.prompt[: self.max_seq - req.max_new_tokens - 1]
            # Prefill this slot (batch=1) and splice its state into the
            # engine cache at the slot index.  The prefill logits give the
            # FIRST generated token; subsequent ticks feed it back.
            t0 = time.perf_counter()
            logits, pref_cache = lm_mod.prefill(
                self.cfg, self.params,
                jnp.asarray(prompt, jnp.int32)[None], self.max_seq,
                tp=self.tp, rules=self.rules,
            )
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.cache, pref_cache,
            )
            first = int(jnp.argmax(logits[0, 0, : self.cfg.vocab_size]))
            self.slot_rid[slot] = req.rid
            self.slot_tokens[slot] = list(prompt) + [first]
            self.slot_prompt_len[slot] = len(prompt)
            self.lens[slot] = len(prompt)
            self.remaining[slot] = req.max_new_tokens - 1
            self.last_token[slot] = first
            self.slot_t0[slot] = t0
            self.timing.count_item(f"slot{slot}")
            if self.remaining[slot] <= 0 or (
                self.eos_id is not None and first == self.eos_id
            ):
                self._complete(slot)

    # -- decode tick -------------------------------------------------------------

    def step(self) -> int:
        """One engine tick.  Returns the number of active slots."""
        self._admit()
        active = self.slot_rid >= 0
        if not active.any():
            return 0
        t0 = time.perf_counter()
        # Note: idle slots decode garbage in lockstep (masked out below) —
        # the SPMD price for batched decode; their cache writes land at
        # their stale lens and are overwritten on admission (prefill).
        tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
        lens = jnp.asarray(self.lens, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens, lens)
        next_tokens = np.asarray(
            jnp.argmax(logits[:, 0, : self.cfg.vocab_size], axis=-1)
        )
        self.timing.add("host", "run", (time.perf_counter() - t0) * 1e3)

        for slot in range(self.max_slots):
            if not active[slot]:
                continue
            tok = int(next_tokens[slot])
            self.slot_tokens[slot].append(tok)
            self.lens[slot] += 1  # last_token is now in the cache
            self.remaining[slot] -= 1
            self.last_token[slot] = tok
            done = (
                self.remaining[slot] <= 0
                or (self.eos_id is not None and tok == self.eos_id)
                or self.lens[slot] >= self.max_seq - 1
            )
            if done:
                self._complete(slot)
        return int(active.sum())

    def _complete(self, slot: int) -> None:
        """afoc/afo -> Collect; the slot goes idle and (demand-driven)
        requests new work on the next tick."""
        self.completions.append(
            Completion(
                rid=int(self.slot_rid[slot]),
                tokens=list(self.slot_tokens[slot]),
                prompt_len=int(self.slot_prompt_len[slot]),
                latency_s=time.perf_counter() - self.slot_t0[slot],
            )
        )
        self.slot_rid[slot] = -1
        self.slot_tokens[slot] = []

    # -- UT ------------------------------------------------------------------------

    def shutdown(self) -> list[Completion]:
        """Propagate the terminator: no new work, drain, return results."""
        self._shutdown = True
        guard = 0
        while (self.slot_rid >= 0).any() or self.queue:
            self.step()
            guard += 1
            if guard > 100000:  # pragma: no cover
                raise RuntimeError("drain did not terminate")
        return self.completions

    def run_until_drained(self) -> list[Completion]:
        while self.queue or (self.slot_rid >= 0).any():
            self.step()
        return self.completions
