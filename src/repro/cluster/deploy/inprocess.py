"""InProcessLauncher: node-loaders as threads of the host process.

The fastest incarnation — no interpreter fork, no pipe plumbing — while
still exercising the *entire* wire protocol: each thread runs the real
:func:`repro.cluster.node_loader.run_node` against the host's TCP socket,
so REGISTER/LOAD/credits/UT all happen over real frames.  Meant for
launcher-logic and placement-policy tests (respawn, degraded start, late
join) where forking interpreters per scenario would dominate the suite.

Caveats, on purpose: threads share the GIL (no perf isolation) and cannot
be SIGKILLed — :meth:`ThreadNodeHandle.kill` only abandons the thread (its
socket dies with the host), which is exactly the "silent node" shape the
placement policy exists to handle.
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Mapping, Sequence

from repro.cluster.deploy.base import Launcher, NodeHandle


class ThreadNodeHandle(NodeHandle):
    """A node-loader running on a daemon thread of this process.

    ``delay`` holds the thread back before it dials — a slow-booting
    workstation in miniature, for exercising the host's silent-node and
    late-join policies without wall-clock-heavy subprocesses.
    """

    def __init__(self, node_id: str, connect_host: str, port: int,
                 connect_timeout: float = 30.0, delay: float = 0.0):
        self.node_id = node_id
        self.where = "thread"
        self.killed = False
        self._exit: int | None = None
        self._log: list[str] = []
        self._conn = None  # the node's FrameConnection, once it dialled

        def target() -> None:
            from repro.cluster.node_loader import run_node

            def on_conn(conn) -> None:
                self._conn = conn

            try:
                if delay > 0.0:
                    time.sleep(delay)
                record = run_node(connect_host, port, node_id=node_id,
                                  connect_timeout=connect_timeout,
                                  on_conn=on_conn)
                self._log.append(f"node-loader done: {record}")
                self._exit = 0
            except BaseException as exc:
                self._log.append(f"node-loader failed: {exc}")
                self._log.extend(traceback.format_exc().splitlines()[-5:])
                self._exit = 1

        self._thread = threading.Thread(target=target,
                                        name=f"inproc-{node_id}", daemon=True)
        self._thread.start()

    def poll(self) -> int | None:
        return self._exit if not self._thread.is_alive() else None

    def wait(self, timeout: float | None = None) -> int | None:
        self._thread.join(timeout=timeout)
        return self.poll()

    def kill(self) -> None:
        # Threads cannot be killed, but a *connected* node can be made dead
        # to the cluster by severing its socket: heartbeats stop, the host
        # reaps it and redispatches its in-flight work — a faithful
        # mid-run crash for the service/failover tests.  An unconnected
        # handle stays a "silent node" (the placement policy's problem).
        self.killed = True
        conn = self._conn
        if conn is not None:
            conn.close()

    def logs(self) -> list[str]:
        return list(self._log)


class InProcessLauncher(Launcher):
    """Runs node-loaders as threads (real sockets, no subprocess cost).

    ``delays`` maps node ids to seconds of pre-dial sleep (slow boots).
    """

    def __init__(self, *, connect_timeout: float = 30.0,
                 delays: Mapping[str, float] | None = None):
        self.connect_timeout = connect_timeout
        self.delays = dict(delays or {})
        self.connect_host = "127.0.0.1"
        self.port = 0
        self.launched: list[str] = []

    def launch(self, node_id: str, *,
               avoid: Sequence[str] = ()) -> ThreadNodeHandle:
        self.launched.append(node_id)
        return ThreadNodeHandle(node_id, self.connect_host, self.port,
                                connect_timeout=self.connect_timeout,
                                delay=self.delays.get(node_id, 0.0))
