"""Wire format of the load and application networks.

Every message is one *frame*::

    +-------+---------+-------+-------+---------+---------+----------+---------+
    | magic | version | ftype | codec | channel | job_id  | length   | payload |
    | 4B    | 1B      | 1B    | 1B    | 1B      | 4B (!I) | 4B (!I)  | len B   |
    +-------+---------+-------+-------+---------+---------+----------+---------+

``ftype`` is the protocol event — the same alphabet as the CSP model in
``core.protocol`` plus the bootstrap events of paper §4 (Figure 1):
REGISTER/LOAD/HEARTBEAT ride the *load network* (channel 1, the paper's
"port 2000 channel 1"), WORK_REQUEST/WORK_BATCH/RESULT_BATCH/UT ride the
*application network* (channel 2).  ``UT`` is the paper's Universal
Terminator made visible on the wire.  WORK/RESULT are the original
one-object-per-frame events; the pipelined data plane coalesces them into
WORK_BATCH/RESULT_BATCH (see ARCHITECTURE.md "Data plane") but both sides
still accept the single-object forms.

``job_id`` (wire version 2) is the multiplexing key of the cluster
*service*: a warm node pool outlives any one job, so every frame names the
job it belongs to — WORK_BATCH/RESULT_BATCH items of two concurrent jobs
interleave on one connection and the host keeps exactly-once state per
job.  ``job_id == 0`` means "no job" (bootstrap / pool-control frames:
REGISTER, HEARTBEAT, the pool-config LOAD, the final UT).

Payload encoding is a three-codec scheme:

* **msgpack** (codec 0) for protocol-internal messages built from plain
  JSON-ish data — cheap, language-neutral.  The encoder is single-pass:
  ``msgpack.packb(strict_types=True, default=...)`` either succeeds or
  raises on the first non-msgpack value (tuple, set, big int, custom
  class), in which case the whole payload falls back to pickle.  ndarrays
  nested inside msgpack payloads are carried as an ExtType (one copy).
* **pickle** (codec 1, via cloudpickle when available) for user objects and
  shipped code (the JCSP code-loading channel analogue of §4.1).
* **ndarray** (codec 2) for a bare ``numpy``/``jax`` array payload: a tiny
  ``(order, dtype, shape)`` header followed by the raw buffer, sent as a
  ``memoryview`` — no pickle and *no copy on encode* for contiguous
  arrays.  Decode is ``np.frombuffer`` over the received bytes (read-only,
  zero-copy).  Object-dtype arrays are not bufferable and take the pickle
  codec instead.
"""

from __future__ import annotations

import enum
import io
import pickle
import socket
import struct
import sys
import threading
from dataclasses import dataclass
from typing import Any

try:
    import cloudpickle as _pickler
except ImportError:  # pragma: no cover - cloudpickle is in the image
    _pickler = pickle

try:
    import msgpack

    _HAVE_MSGPACK = True
except ImportError:  # pragma: no cover
    _HAVE_MSGPACK = False

MAGIC = b"CGPP"
VERSION = 2  # v2 added the job_id header field (multi-job multiplexing)
LOAD_WIRE_CHANNEL = 1  # paper §6: the load network uses channel number 1
APP_WIRE_CHANNEL = 2  # the application network runs on a separate channel

# Warm-code cache slots per node: deserialized stage functions keyed by
# payload digest.  The host mirrors each node's LRU with the same capacity
# and the same touch order (frames arrive in send order on one TCP stream),
# so it knows exactly which digests a node still holds and can skip
# re-shipping code on a warm resubmit.
CODE_CACHE_SLOTS = 32

# One liveness default shared by the node beacon (pre- and post-LOAD) and the
# host's HeartbeatMonitor threshold, so neither side beats at a rate the
# other does not expect.
DEFAULT_HEARTBEAT_S = 0.2

# Guards against a corrupt length field consuming the heap.
MAX_FRAME_BYTES = 512 * 2**20

# Broadcast blocks travel as fixed-size chunks so N nodes can stripe their
# fetches (each asks the host for a disjoint subset, then peers trade the
# rest).  1 MiB keeps any single BLOCK_CHUNK frame well under the socket
# buffer while amortising the per-frame header.
BLOCK_CHUNK_BYTES = 1 << 20

# Complete blocks an individual node keeps resident, LRU-evicted like the
# warm code cache: enough for a weights blob plus a few lookup tables, small
# enough that an immortal pool node cannot grow without bound.
BLOCK_CACHE_SLOTS = 8

_HEADER = struct.Struct("!4sBBBBII")

# How deep the socket's buffered reader reads ahead: one recv syscall
# typically yields many small frames instead of 2+ recvs per frame.
READ_BUFFER_BYTES = 1 << 16


class FrameType(enum.IntEnum):
    REGISTER = 1  # NL -> HNL: node id + capabilities (load network)
    LOAD = 2  # HNL -> NL: serialized deployment (code-loading channel)
    WORK_REQUEST = 3  # NL -> HNL: demand signal carrying a credit count
    WORK = 4  # HNL -> NL: one work object (c!i.o) — legacy single form
    RESULT = 5  # NL -> HNL: one processed object (f!r) — legacy single form
    HEARTBEAT = 6  # NL -> HNL: liveness beacon (load network)
    UT = 7  # either direction: Universal Terminator / timing return
    WORK_BATCH = 8  # HNL -> NL: up to `credits` work objects in one frame
    RESULT_BATCH = 9  # NL -> HNL: coalesced results + piggybacked credits
    JOB_CLOSE = 10  # HNL -> NL: job finished/failed — drop its bindings
    REPORT = 11  # NL -> HNL: node telemetry push (load network, off-beat)
    ITEM_ACK = 12  # NL -> HNL: peer-forwarded item ids + piggybacked credits
    PEER_ITEMS = 13  # NL -> NL: stage-s results shipped directly as s+1 work
    PEER_HELLO = 14  # NL -> NL: data-plane handshake (sender's node id)
    BLOCK_REQUEST = 15  # NL -> HNL/NL: ask for one chunk of a published block
    BLOCK_CHUNK = 16  # HNL/NL -> NL: one block chunk (data=None on a miss)


class _CodecId(enum.IntEnum):
    MSGPACK = 0
    PICKLE = 1
    NDARRAY = 2


# msgpack ExtType code for an ndarray embedded in a larger payload.
_EXT_NDARRAY = 1


class UniversalTerminator:
    """The paper's UT object (§4, Listing 3 {3:21}), wire edition."""

    _instance: "UniversalTerminator | None" = None

    def __new__(cls) -> "UniversalTerminator":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "UT"


UT = UniversalTerminator()


@dataclass(frozen=True)
class Frame:
    ftype: FrameType
    payload: Any = None
    channel: int = APP_WIRE_CHANNEL
    job_id: int = 0  # 0 = not job-scoped (bootstrap / pool control)


# ---------------------------------------------------------------------------
# ndarray codec (codec 2 / ExtType 1)
# ---------------------------------------------------------------------------


def _as_wire_array(obj: Any):
    """A numpy view of ``obj`` if it is a bufferable array, else None.

    ``sys.modules.get`` instead of an import: if numpy was never imported in
    this process, ``obj`` cannot be an ndarray, and the bare node-loader
    bootstrap stays dependency-free.
    """
    np = sys.modules.get("numpy")
    # getattr guards: another thread may be mid-import (a worker pulling in
    # the shipped code's deps), leaving a partially initialized module in
    # sys.modules — in which case obj cannot be an array of that module yet.
    ndarray = getattr(np, "ndarray", None)
    if ndarray is None:
        return None
    if isinstance(obj, ndarray):
        return obj if _bufferable_dtype(obj.dtype) else None
    jax_array = getattr(sys.modules.get("jax"), "Array", None)
    if jax_array is not None and isinstance(obj, jax_array):
        try:
            a = np.asarray(obj)  # zero-copy for committed CPU arrays
        except Exception:
            return None
        return a if _bufferable_dtype(a.dtype) else None
    return None


def _bufferable_dtype(dtype) -> bool:
    """Only plain builtin dtypes ride the raw-buffer codec.

    ``dtype.str`` is the whole header, so anything it does not fully
    describe must take pickle instead: structured/record dtypes would
    silently drop their field names ('|V8'), datetime64/timedelta64 refuse
    buffer export, and object arrays are not buffers at all.
    """
    return dtype.kind in "biufcSU" and dtype.names is None


def _ndarray_buffers(a) -> list:
    """Encode one ndarray as ``[header, raw-buffer]``.

    The raw buffer is a memoryview of the array's own memory (zero-copy)
    for C- and F-contiguous arrays; only non-contiguous arrays pay one
    compaction copy.  F-order ships the bytes as laid out (via the
    C-contiguous transpose view) with an order flag so decode rebuilds the
    exact array.
    """
    import numpy as np

    if a.flags.c_contiguous:
        order, view = 0, a
    elif a.flags.f_contiguous:
        order, view = 1, a.T  # C-contiguous view over the same buffer
    else:
        order, view = 0, np.ascontiguousarray(a)
    dt = a.dtype.str.encode("ascii")
    header = (
        struct.pack(f"!BB{len(dt)}sB", order, len(dt), dt, a.ndim)
        + struct.pack(f"!{a.ndim}Q", *a.shape)
    )
    if view.size == 0:  # a zero in the shape cannot be cast to 'B'
        return [header, b""]
    return [header, memoryview(view).cast("B")]


def _decode_ndarray(raw) -> Any:
    try:
        import numpy as np
    except ImportError:  # pragma: no cover - symmetric environments
        raise RuntimeError("received ndarray frame but numpy unavailable")
    mv = memoryview(raw)
    order, dlen = struct.unpack_from("!BB", mv, 0)
    dtype = np.dtype(bytes(mv[2 : 2 + dlen]).decode("ascii"))
    (ndim,) = struct.unpack_from("!B", mv, 2 + dlen)
    off = 3 + dlen
    shape = struct.unpack_from(f"!{ndim}Q", mv, off)
    off += 8 * ndim
    arr = np.frombuffer(mv[off:], dtype=dtype)  # read-only, zero-copy
    return arr.reshape(shape, order="F" if order else "C")


def _msgpack_default(obj: Any):
    """Single-pass hook: arrays become an ExtType, anything else aborts the
    msgpack attempt (TypeError) and the payload falls back to pickle."""
    a = _as_wire_array(obj)
    if a is not None:
        header, raw = _ndarray_buffers(a)
        return msgpack.ExtType(_EXT_NDARRAY, header + bytes(raw))
    raise TypeError(f"not msgpack-encodable: {type(obj).__name__}")


def _msgpack_ext_hook(code: int, data: bytes):
    if code == _EXT_NDARRAY:
        return _decode_ndarray(data)
    return msgpack.ExtType(code, data)  # pragma: no cover - foreign ext


# ---------------------------------------------------------------------------
# payload codecs
# ---------------------------------------------------------------------------


def encode_payload(obj: Any) -> tuple[int, list]:
    """Encode ``obj`` to ``(codec, buffer list)`` in a single pass.

    A bare ndarray takes the zero-copy ndarray codec.  Everything else is
    attempted as msgpack (``strict_types`` keeps tuples exact by rejecting
    them) and falls back to pickle on the first non-msgpack value — no
    pre-pass traversal of the payload.  Payloads too deep for *any* codec
    raise a clear ValueError instead of a RecursionError from inside a
    serializer.
    """
    a = _as_wire_array(obj)
    if a is not None:
        try:
            return _CodecId.NDARRAY, _ndarray_buffers(a)
        except (TypeError, ValueError, struct.error):
            pass  # exotic dtype/layout the buffer codec cannot express
    if _HAVE_MSGPACK:
        try:
            return _CodecId.MSGPACK, [
                msgpack.packb(
                    obj,
                    use_bin_type=True,
                    strict_types=True,
                    default=_msgpack_default,
                )
            ]
        except (TypeError, ValueError, OverflowError, RecursionError):
            pass  # tuples, sets, big ints, custom classes, deep nesting
    try:
        return _CodecId.PICKLE, [_pickler.dumps(obj)]
    except RecursionError:
        raise ValueError(
            "payload nested too deeply for the wire codecs; "
            "flatten it before sending"
        ) from None
    except pickle.PicklingError as exc:
        # cloudpickle wraps the RecursionError; keep the clear diagnosis.
        if "recursion" in str(exc).lower():
            raise ValueError(
                "payload nested too deeply for the wire codecs; "
                "flatten it before sending"
            ) from None
        raise


def decode_payload(codec: int, raw) -> Any:
    if codec == _CodecId.MSGPACK:
        if not _HAVE_MSGPACK:  # pragma: no cover - symmetric environments
            raise RuntimeError("received msgpack frame but msgpack unavailable")
        return msgpack.unpackb(
            raw, raw=False, strict_map_key=False, ext_hook=_msgpack_ext_hook
        )
    if codec == _CodecId.PICKLE:
        return pickle.loads(raw)
    if codec == _CodecId.NDARRAY:
        return _decode_ndarray(raw)
    raise ValueError(f"unknown payload codec {codec}")


def _buffers_len(buffers) -> int:
    return sum(len(b) for b in buffers)


def pack_frame_buffers(frame: Frame) -> list:
    """Pack to ``[header, payload buffers...]`` without flattening.

    Callers that own a socket hand the list to ``sendmsg`` (scatter-gather:
    one syscall, zero concatenation); ``pack_frame`` flattens for callers
    that need contiguous bytes.
    """
    codec, bufs = encode_payload(frame.payload)
    length = _buffers_len(bufs)
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame payload too large: {length} bytes")
    header = _HEADER.pack(
        MAGIC, VERSION, int(frame.ftype), int(codec), frame.channel,
        frame.job_id, length,
    )
    return [header, *bufs]


def pack_frame(frame: Frame) -> bytes:
    return b"".join(
        b if isinstance(b, bytes) else b.tobytes()
        for b in pack_frame_buffers(frame)
    )


def unpack_frame(buf: bytes) -> Frame:
    return read_frame(io.BytesIO(buf).read)


def _read_exactly(read, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = read(remaining)
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        remaining -= len(chunk)
    return chunks[0] if len(chunks) == 1 else b"".join(chunks)


def _read_frame_counted(read) -> tuple[Frame, int]:
    header = _read_exactly(read, _HEADER.size)
    magic, version, ftype, codec, channel, job_id, length = (
        _HEADER.unpack(header)
    )
    if magic != MAGIC:
        raise ValueError(f"bad frame magic {magic!r}")
    if version != VERSION:
        raise ValueError(f"unsupported wire version {version}")
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {length} exceeds cap")
    raw = _read_exactly(read, length) if length else b""
    frame = Frame(
        FrameType(ftype), decode_payload(codec, raw), channel, job_id
    )
    return frame, _HEADER.size + length


def read_frame(read) -> Frame:
    """Read one frame from any ``read(n) -> bytes`` source (socket, buffer)."""
    return _read_frame_counted(read)[0]


@dataclass
class WireCounters:
    """Per-connection traffic counters (bytes/frames each way).

    Mutated under the connection's send lock (send side) and by the single
    reader thread (recv side); reads from other threads see a consistent
    enough snapshot for reporting.
    """

    frames_sent: int = 0
    frames_recv: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0

    def as_dict(self) -> dict[str, int]:
        return {
            "frames_sent": self.frames_sent,
            "frames_recv": self.frames_recv,
            "bytes_sent": self.bytes_sent,
            "bytes_recv": self.bytes_recv,
        }

    def merge(self, other: "WireCounters | dict") -> None:
        """Fold another connection's counters into this one (cluster-wide
        totals for telemetry/timing reports)."""
        d = other.as_dict() if isinstance(other, WireCounters) else other
        self.frames_sent += d.get("frames_sent", 0)
        self.frames_recv += d.get("frames_recv", 0)
        self.bytes_sent += d.get("bytes_sent", 0)
        self.bytes_recv += d.get("bytes_recv", 0)

    @classmethod
    def total(cls, counters: "list[WireCounters]") -> "WireCounters":
        out = cls()
        for c in counters:
            out.merge(c)
        return out


class FrameConnection:
    """A framed, thread-safe view of one TCP socket.

    Many threads may ``send`` (workers delivering results while the heartbeat
    thread beats); exactly one thread should ``recv`` — the reader owns frame
    routing (see :mod:`repro.cluster.netchannels`).  Receives go through a
    buffered reader so one kernel ``recv`` serves many small frames; sends go
    through ``sendmsg`` scatter-gather so a frame (header + payload buffers)
    is one syscall with no concatenation copy.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self._closed = False
        self.counters = WireCounters()
        self._rfile = sock.makefile("rb", buffering=READ_BUFFER_BYTES)
        # TCP_NODELAY: frames are small and latency-sensitive (demand signals).
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # pragma: no cover - non-TCP sockets in tests
            pass

    @property
    def peer(self) -> str:
        try:
            name = self.sock.getpeername()
        except OSError:
            return "<closed>"
        if isinstance(name, tuple) and len(name) >= 2:
            return f"{name[0]}:{name[1]}"
        return str(name) or "<unnamed>"  # AF_UNIX pairs have no address

    def send(self, frame: Frame) -> None:
        bufs = pack_frame_buffers(frame)
        self.send_raw(bufs)

    def send_raw(self, bufs: list) -> None:
        """Send pre-packed frame buffers (``[header, *payload]``) verbatim.

        Lets callers that need byte-level control over the wire image —
        the chaos layer's payload-corruption fault — reuse the locked
        scatter-gather path instead of poking at the socket directly.
        """
        total = _buffers_len(bufs)
        with self._send_lock:
            self._send_buffers(bufs, total)
            self.counters.frames_sent += 1
            self.counters.bytes_sent += total

    def _send_buffers(self, bufs: list, total: int) -> None:
        try:
            sent = self.sock.sendmsg(bufs)
        except AttributeError:  # pragma: no cover - no scatter-gather here
            self.sock.sendall(
                b"".join(b if isinstance(b, bytes) else b.tobytes()
                         for b in bufs)
            )
            return
        if sent == total:
            return
        for b in bufs:  # short write: finish the remaining tail
            n = len(b)
            if sent >= n:
                sent -= n
                continue
            mv = memoryview(b)
            self.sock.sendall(mv[sent:] if sent else mv)
            sent = 0

    def recv(self) -> Frame:
        frame, nbytes = _read_frame_counted(self._rfile.read)
        self.counters.frames_recv += 1
        self.counters.bytes_recv += nbytes
        return frame

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            # Unblocks a reader parked in recv before we tear the fd down.
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()
        try:
            self._rfile.close()
        except (OSError, ValueError):
            pass


def dumps_code(obj: Any) -> bytes:
    """Serialise shipped code (work functions, details) by value.

    cloudpickle captures closures and locally-defined functions; plain pickle
    (the fallback) requires them to be importable on the node — which the
    launcher guarantees by exporting the host's ``sys.path``.
    """
    return _pickler.dumps(obj)


def loads_code(raw: bytes) -> Any:
    return pickle.loads(raw)
