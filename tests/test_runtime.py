"""Runtime integration: fault-tolerant trainer, serving engine, stragglers."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.models import lm
from repro.models.common import init_params
from repro.optim.adamw import AdamWConfig
from repro.runtime.executor import Trainer, TrainerConfig
from repro.runtime.failures import FailureEvent, FailurePlan, StragglerMonitor
from repro.runtime.serving import Request, ServingEngine

TINY = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def _trainer(tmp, steps=10, failure_plan=None, resume=True):
    cfg = get_config("yi-9b").smoke()
    return Trainer(
        cfg, TINY,
        TrainerConfig(num_steps=steps, checkpoint_every=4, checkpoint_dir=tmp,
                      warmup_steps=2, resume=resume),
        opt_cfg=AdamWConfig(),
        failure_plan=failure_plan or FailurePlan(),
    )


def test_trainer_runs_and_checkpoints():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=9)
        out = tr.run()
        assert out["final_step"] == 9
        assert out["restarts"] == 0
        assert tr.ckpt.latest_step() == 9
        assert all(np.isfinite(m["loss"]) for m in tr.metrics_history)


def test_trainer_crash_restart_is_deterministic():
    """After an injected crash, restore + replay must produce bit-identical
    losses for the replayed steps (checkpoint + deterministic data)."""
    with tempfile.TemporaryDirectory() as d:
        plan = FailurePlan([FailureEvent(step=6, kind="crash")])
        tr = _trainer(d, steps=10, failure_plan=plan)
        out = tr.run()
        assert out["restarts"] == 1
        by_step = {}
        replay_deltas = []
        for m in tr.metrics_history:
            if m["step"] in by_step:
                replay_deltas.append(abs(by_step[m["step"]] - m["loss"]))
            by_step[m["step"]] = m["loss"]
        assert replay_deltas, "crash should force replayed steps"
        assert max(replay_deltas) == 0.0


def test_trainer_resume_across_instances():
    with tempfile.TemporaryDirectory() as d:
        tr1 = _trainer(d, steps=4)
        tr1.run()
        tr2 = _trainer(d, steps=8)
        assert tr2.step0 == 4  # picked up the checkpoint
        out = tr2.run()
        assert out["final_step"] == 8


def test_restart_budget_exhaustion():
    with tempfile.TemporaryDirectory() as d:
        plan = FailurePlan([FailureEvent(step=s, kind="crash")
                            for s in (2, 2, 2, 2, 2, 2)])
        tr = _trainer(d, steps=6, failure_plan=plan)
        tr.cfg.max_restarts = 2
        with pytest.raises(RuntimeError, match="restart budget"):
            tr.run()


def test_straggler_monitor_detects():
    mon = StragglerMonitor(threshold=2.0)
    detected = [mon.record(0.1) for _ in range(10)]
    assert not any(detected)
    assert mon.record(0.5) is True
    assert mon.record(0.1) is False


def test_serving_engine_matches_offline_decode():
    cfg = dataclasses.replace(get_config("gemma3-4b").smoke(),
                              compute_dtype="float32")
    params = init_params(lm.lm_param_specs(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    eng = ServingEngine(cfg, params, max_slots=2, max_seq=48)
    rng = np.random.default_rng(1)
    for rid in range(4):
        eng.submit(Request(
            rid=rid,
            prompt=list(map(int, rng.integers(0, cfg.vocab_size,
                                              int(rng.integers(3, 10))))),
            max_new_tokens=int(rng.integers(2, 6)),
        ))
    done = eng.shutdown()
    assert len(done) == 4
    for c in done:
        prompt, gen = c.tokens[: c.prompt_len], c.tokens[c.prompt_len:]
        logits, cache = lm.prefill(cfg, params,
                                   jnp.asarray(prompt, jnp.int32)[None],
                                   max_seq=48)
        out = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
        last, clen = out[0], len(prompt)
        for _ in range(len(gen) - 1):
            lg, cache = lm.decode_step(cfg, params, cache,
                                       jnp.asarray([[last]], jnp.int32),
                                       jnp.int32(clen))
            last = int(jnp.argmax(lg[0, 0, : cfg.vocab_size]))
            clen += 1
            out.append(last)
        assert gen == out, f"rid {c.rid}"


def test_serving_engine_demand_driven_idle_slots():
    """More requests than slots: every slot processes some work (the onrl
    server answers whichever slot requests next)."""
    cfg = dataclasses.replace(get_config("yi-9b").smoke(),
                              compute_dtype="float32")
    params = init_params(lm.lm_param_specs(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    eng = ServingEngine(cfg, params, max_slots=3, max_seq=48)
    for rid in range(9):
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=3))
    done = eng.shutdown()
    assert sorted(c.rid for c in done) == list(range(9))
    items = {t.node_id: t.items for t in eng.timing.nodes
             if t.node_id.startswith("slot")}
    assert all(v > 0 for v in items.values())
    assert sum(items.values()) == 9
