"""Executable emit/cluster/collect network (the paper's Figure 2), local mode.

This is the runtime behind ``ClusterBuilder.build_application``: the wired
process network running as threads with bounded rendezvous channels on one
machine — precisely the paper's §6.1 *"operation and testing of a system can
be conducted on a single host node before using multiple nodes"* mode.  The
topology, the demand-driven client-server protocol (``onrl``/``nrfa``), the
one-place buffer invariant and Universal-Terminator shutdown are the ones
model-checked in ``core.verify``; this module is their operational twin.

Worker functions are expected to be JAX/numpy computations: XLA releases the
GIL during execution, so worker threads genuinely overlap (Table 1 of the
paper is reproduced this way in ``benchmarks/``).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.core.builder import DeploymentPlan
from repro.core.dsl import ClusterSpec
from repro.core.timing import TimingCollector


class _UT:
    """Universal Terminator (paper §4, Listing 3 {3:21})."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "UT"


UT = _UT()


@dataclass
class LocalClusterApplication:
    spec: ClusterSpec
    plan: DeploymentPlan
    timing: TimingCollector

    result: Any = None
    _ran: bool = False

    def run(self) -> Any:
        """Load the network, run to termination, return the finalised result."""
        if self._ran:
            raise RuntimeError("application already ran; build a fresh one")
        self._ran = True
        spec = self.spec
        n, w = spec.nclusters, spec.workers_per_node

        with self.timing.phase("host", "load"):
            # -- channel construction (input ends before output ends, §6) --
            emit_to_onrl: queue.Queue = queue.Queue(maxsize=1)  # a
            request_q: queue.Queue = queue.Queue()  # b.* many-to-one
            node_in = [queue.Queue(maxsize=1) for _ in range(n)]  # c.i
            work_q = [queue.Queue(maxsize=1) for _ in range(n)]  # d.i (1-place)
            afoc_q = [queue.Queue(maxsize=w) for _ in range(n)]  # e.i
            afo_q: queue.Queue = queue.Queue()  # node merge -> afo
            collect_q: queue.Queue = queue.Queue()  # f

            threads: list[threading.Thread] = []

            def _spawn(fn, *args, name: str) -> None:
                t = threading.Thread(target=fn, args=args, name=name, daemon=True)
                threads.append(t)

            # ---- host: Emit ------------------------------------------------
            def emit_proc() -> None:
                details = spec.host_net.emit.e_details
                state = details.initial_state()
                while True:
                    item, state = details.create(state)
                    if item is None:  # normalTermination
                        emit_to_onrl.put(UT)
                        return
                    emit_to_onrl.put(item)

            # ---- host: onrl (server) ----------------------------------------
            def onrl_proc() -> None:
                while True:
                    obj = emit_to_onrl.get()
                    if obj is UT:
                        # Server_End: answer each node's next request with UT.
                        for _ in range(n):
                            node = request_q.get()
                            node_in[node].put(UT)
                        return
                    node = request_q.get()  # wait for a request from any node
                    node_in[node].put(obj)  # answer it in finite time

            # ---- per node: nrfa (client, one-place buffer) -------------------
            def nrfa_proc(i: int) -> None:
                with self.timing.phase(f"node{i}", "load"):
                    pass  # channel ends created above; record the touchpoint
                t0 = time.perf_counter()
                while True:
                    request_q.put(i)  # b!i.S — only after previous delivery
                    obj = node_in[i].get()  # c?i.o
                    if obj is UT:
                        for _ in range(w):
                            work_q[i].put(UT)
                        break
                    work_q[i].put(obj)  # d!i.o (blocks until a worker idles)
                self.timing.add(f"node{i}", "run", (time.perf_counter() - t0) * 1e3)

            # ---- per node: workers -------------------------------------------
            def worker_proc(i: int, _wi: int) -> None:
                fn = spec.node_net.group.function
                while True:
                    obj = work_q[i].get()
                    if obj is UT:
                        afoc_q[i].put(UT)
                        return
                    afoc_q[i].put(fn(obj))
                    self.timing.count_item(f"node{i}")

            # ---- per node: afoc (merge workers, net output) -------------------
            def afoc_proc(i: int) -> None:
                remaining = w
                while remaining:
                    obj = afoc_q[i].get()
                    if obj is UT:
                        remaining -= 1
                        continue
                    afo_q.put(obj)
                afo_q.put(UT)  # single UT per node

            # ---- host: afo + collect ------------------------------------------
            def afo_proc() -> None:
                remaining = n
                while remaining:
                    obj = afo_q.get()
                    if obj is UT:
                        remaining -= 1
                        continue
                    collect_q.put(obj)
                collect_q.put(UT)

            def collect_proc() -> None:
                details = spec.host_net.collector.r_details
                acc = details.init()
                while True:
                    obj = collect_q.get()
                    if obj is UT:
                        self.result = details.finalise(acc)
                        return
                    acc = details.collect(acc, obj)

            _spawn(emit_proc, name="emit")
            _spawn(onrl_proc, name="onrl")
            for i in range(n):
                _spawn(nrfa_proc, i, name=f"nrfa{i}")
                for wi in range(w):
                    _spawn(worker_proc, i, wi, name=f"worker{i}.{wi}")
                _spawn(afoc_proc, i, name=f"afoc{i}")
            _spawn(afo_proc, name="afo")
            _spawn(collect_proc, name="collect")

        with self.timing.phase("host", "run"):
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        return self.result
