"""repro.cluster.deploy — the pluggable deployment layer.

*How* node-loaders come into existence is orthogonal to everything else in
the cluster subsystem (the wire protocol, the credit pipeline, membership):
the paper's node side is one identical executable that needs only the
host's load address.  This package isolates that concern behind the
:class:`~repro.cluster.deploy.base.Launcher` contract:

* :class:`LocalLauncher` — subprocesses on this machine (§6.1 single-host
  confidence building; the seed behaviour);
* :class:`SSHLauncher` — the same command fanned out over ssh to idle
  workstations, with rsync / tar-over-ssh code sync;
* :class:`InProcessLauncher` — node-loaders as threads (fast
  launcher-logic and placement-policy tests).

:class:`PlacementPolicy` is the host-side companion: what the registration
barrier does when launches misbehave (respawn silent nodes, degraded start
with ``min_nodes`` survivors, late join mid-run).
"""

from repro.cluster.deploy.base import (  # noqa: F401
    Launcher,
    NodeHandle,
    PlacementPolicy,
)
from repro.cluster.deploy.inprocess import (  # noqa: F401
    InProcessLauncher,
    ThreadNodeHandle,
)
from repro.cluster.deploy.local import (  # noqa: F401
    LocalLauncher,
    PopenNodeHandle,
    node_loader_argv,
    spawn_node_loader,
)
from repro.cluster.deploy.ssh import SSHLauncher  # noqa: F401
