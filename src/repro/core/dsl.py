"""The ClusterBuilder DSL.

The paper's DSL (Listing 1) is a Groovy source file with three cluster
annotations::

    01. ... constants used in definition
    02. //@emit host-ip
    03. ... emit process definition
    04. //@cluster Nclusters
    05. ... cluster process definition
    06. //@collect
    07. ... collect process definition

We keep the textual front end *faithful* — a ``.cgpp`` file with the same
``//@emit`` / ``//@cluster`` / ``//@collect`` annotations, whose sections are
Python instead of Groovy — and we additionally expose the same structure as a
plain Python API (:class:`ClusterSpec`).  Both produce identical specs; the
builder (``core.builder``) consumes a :class:`ClusterSpec` and derives the
entire deployment (requirements 3, 4 and 6: minimal user code, automatic
network construction, no knowledge of the interconnect).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from repro.core.processes import (
    AnyFanOne,
    AnyGroupAny,
    Collect,
    Emit,
    EmitDetails,
    HostNetwork,
    NodeNetwork,
    NodeRequestingFanAny,
    OneNodeRequestedList,
    ProcessRecord,
    ResultDetails,
)

_EMIT_RE = re.compile(r"^//@emit\s+(?P<host>\S+)\s*$")
_CLUSTER_RE = re.compile(r"^//@cluster\s+(?P<n>\S+)\s*$")
_COLLECT_RE = re.compile(r"^//@collect\s*$")


@dataclass
class ClusterSpec:
    """A parsed/constructed ClusterBuilder application specification.

    Attributes:
      host: IP (or symbolic name) of the host node — the only piece of
        network knowledge the user must supply (requirement 6).
      nclusters: number of cluster nodes (``//@cluster N``).
      workers_per_node: worker processes per node ("cores" in Listing 2).
      host_net / node_net: the declarative process records.
      constants: the constants section of the DSL file, for provenance.
    """

    host: str
    nclusters: int
    host_net: HostNetwork
    node_net: NodeNetwork
    constants: dict[str, Any] = field(default_factory=dict)

    @property
    def workers_per_node(self) -> int:
        return self.node_net.group.workers

    @property
    def total_workers(self) -> int:
        return self.nclusters * self.workers_per_node

    def validate(self) -> None:
        """Static validation of the canonical emit->cluster->collect topology.

        The paper's builder only accepts well-formed specs; violations are
        caught *before* deployment (this mirrors gppBuilder's checks).
        """
        if self.nclusters < 1:
            raise ValueError(f"nclusters must be >= 1, got {self.nclusters}")
        if self.workers_per_node < 1:
            raise ValueError(
                f"workers per node must be >= 1, got {self.workers_per_node}"
            )
        if self.host_net.afo.sources != self.nclusters:
            raise ValueError(
                "host AnyFanOne.sources must equal nclusters "
                f"({self.host_net.afo.sources} != {self.nclusters}); the "
                "result-merge process reads one stream per node"
            )
        # NodeNetwork.__post_init__ already enforced intra-node consistency.
        if not callable(self.node_net.group.function):
            raise TypeError("cluster group function must be callable")

    # -- convenience constructor -------------------------------------------

    @staticmethod
    def simple(
        *,
        host: str,
        nclusters: int,
        workers_per_node: int,
        emit_details: EmitDetails,
        work_function: Callable[[Any], Any],
        result_details: ResultDetails,
        constants: Mapping[str, Any] | None = None,
    ) -> "ClusterSpec":
        """Build the canonical network of Figure 2 from user callables only."""
        host_net = HostNetwork(
            emit=Emit(e_details=emit_details),
            onrl=OneNodeRequestedList(),
            afo=AnyFanOne(sources=nclusters),
            collector=Collect(r_details=result_details),
        )
        node_net = NodeNetwork(
            nrfa=NodeRequestingFanAny(destinations=workers_per_node),
            group=AnyGroupAny(workers=workers_per_node, function=work_function),
            afoc=AnyFanOne(sources=workers_per_node),
        )
        spec = ClusterSpec(
            host=host,
            nclusters=nclusters,
            host_net=host_net,
            node_net=node_net,
            constants=dict(constants or {}),
        )
        spec.validate()
        return spec


def parse_cgpp(text: str, namespace: Mapping[str, Any] | None = None) -> ClusterSpec:
    """Parse a ``.cgpp`` DSL file into a :class:`ClusterSpec`.

    The file has four sections delimited by the three annotations, exactly as
    Listing 1.  Section bodies are executed as Python with the process record
    classes pre-bound (the paper binds the Groovy GPP classes the same way via
    the ``cgpp`` file association, §6.1).  ``namespace`` supplies the user's
    data classes (e.g. ``Mdata``/``Mcollect`` equivalents).
    """
    sections: dict[str, list[str]] = {
        "constants": [],
        "emit": [],
        "cluster": [],
        "collect": [],
    }
    host: str | None = None
    ncluster_expr: str | None = None
    current = "constants"
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        stripped = line.strip()
        m = _EMIT_RE.match(stripped)
        if m:
            if current != "constants":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — "
                    + ("duplicate //@emit annotation" if host is not None
                       else "//@emit must appear before //@cluster and //@collect")
                )
            host = m.group("host")
            current = "emit"
            continue
        m = _CLUSTER_RE.match(stripped)
        if m:
            if current != "emit":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — "
                    + ("duplicate //@cluster annotation"
                       if ncluster_expr is not None
                       else "//@cluster must follow the emit section")
                )
            ncluster_expr = m.group("n")
            current = "cluster"
            continue
        if _COLLECT_RE.match(stripped):
            if current == "collect":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — duplicate //@collect "
                    "annotation"
                )
            if current != "cluster":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — //@collect must follow "
                    "the cluster section"
                )
            current = "collect"
            continue
        if stripped.startswith("//@"):
            # An annotation-looking line that matched none of the three
            # forms: report it rather than silently treating it as code.
            raise SyntaxError(
                f"line {lineno}: malformed annotation {stripped!r} — "
                "expected '//@emit <host-ip>', '//@cluster <N>' or "
                "'//@collect'"
            )
        sections[current].append(line)

    if host is None:
        raise SyntaxError("missing //@emit <host-ip> annotation")
    if ncluster_expr is None:
        raise SyntaxError("missing //@cluster <N> annotation")
    if current != "collect":
        raise SyntaxError("missing //@collect annotation")

    env: dict[str, Any] = {
        # Process records, bound like the GPP classes in the paper's IDE setup.
        "Emit": Emit,
        "OneNodeRequestedList": OneNodeRequestedList,
        "NodeRequestingFanAny": NodeRequestingFanAny,
        "AnyGroupAny": AnyGroupAny,
        "AnyFanOne": AnyFanOne,
        "Collect": Collect,
        "EmitDetails": EmitDetails,
        "DataDetails": EmitDetails,  # paper's name for the emit-side details
        "ResultDetails": ResultDetails,
    }
    env.update(namespace or {})

    exec("\n".join(sections["constants"]), env)  # noqa: S102 - DSL execution
    constants = {
        k: v
        for k, v in env.items()
        if isinstance(v, (int, float, str, bool)) and not k.startswith("_")
    }

    # nclusters may reference a constant (Listing 2 uses `clusters`).
    nclusters = int(eval(ncluster_expr, env))  # noqa: S307 - DSL expression

    exec("\n".join(sections["emit"]), env)  # noqa: S102
    exec("\n".join(sections["cluster"]), env)  # noqa: S102
    exec("\n".join(sections["collect"]), env)  # noqa: S102

    records = {k: v for k, v in env.items() if isinstance(v, ProcessRecord)}

    def _one(cls: type) -> Any:
        found = [v for v in records.values() if type(v) is cls]
        if len(found) != 1 and cls is not AnyFanOne:
            raise SyntaxError(
                f"specification must define exactly one {cls.__name__}, "
                f"found {len(found)}"
            )
        return found[0] if found else None

    emit = _one(Emit)
    onrl = _one(OneNodeRequestedList)
    nrfa = _one(NodeRequestingFanAny)
    group = _one(AnyGroupAny)
    collector = _one(Collect)
    fans = [v for v in records.values() if type(v) is AnyFanOne]
    if len(fans) != 2:
        raise SyntaxError(
            f"specification must define exactly two AnyFanOne processes "
            f"(afoc per node + afo at host), found {len(fans)}"
        )
    # Disambiguate by sources: afoc merges the node's workers, afo the nodes.
    afoc = next((f for f in fans if f.sources == group.workers), None)
    afo = next((f for f in fans if f is not afoc), None)
    if afoc is None or afo is None:
        raise SyntaxError(
            "cannot identify afoc (sources == workers) among AnyFanOne records"
        )

    spec = ClusterSpec(
        host=host,
        nclusters=nclusters,
        host_net=HostNetwork(emit=emit, onrl=onrl, afo=afo, collector=collector),
        node_net=NodeNetwork(nrfa=nrfa, group=group, afoc=afoc),
        constants=constants,
    )
    spec.validate()
    return spec


def load_cgpp(path: str, namespace: Mapping[str, Any] | None = None) -> ClusterSpec:
    with open(path, "r", encoding="utf-8") as fh:
        return parse_cgpp(fh.read(), namespace)
