"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
(arXiv:2308.11596; hf).  Backbone only: 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 (padded to 256208 at tp=16).
The speech frontend is a stub supplying precomputed frame embeddings to the
encoder.  Decode shapes exercise the decoder (self-cache + static cross-KV);
long_500k is skipped (full-attention decoder)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    encoder_layers=24,
    frontend="audio",
)
