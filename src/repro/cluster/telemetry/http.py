"""The HTTP status endpoint: stdlib ``http.server``, zero new deps.

A :class:`TelemetryServer` wraps one :class:`~.registry.Telemetry` and
serves, on a daemon thread:

* ``GET /``                    — the self-contained live dashboard (HTML);
* ``GET /metrics``             — full JSON snapshot;
* ``GET /metrics?format=prom`` — Prometheus text exposition;
* ``GET /jobs`` / ``GET /nodes`` — the snapshot's job/node sections;
* ``GET /events?since=N``      — ring events after cursor ``N`` (JSON,
  with ``next`` = the cursor to pass on the following poll);
* anything else                — 404; a malformed query (``since=x``) — 400.

Read-only by construction: every route is a snapshot read, no handler
mutates cluster state, so exposing it beside a live dispatcher is safe.
``ThreadingHTTPServer`` keeps a slow scraper from blocking the dashboard
poll; handlers touch only the thread-safe registry.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.cluster.telemetry.dashboard import DASHBOARD_HTML
from repro.cluster.telemetry.registry import Telemetry

__all__ = ["TelemetryServer"]


class TelemetryServer:
    """Serve one registry over HTTP (see module docstring).

    ``port=0`` binds an ephemeral port (tests); the chosen one is in
    ``.port`` / ``.url`` after construction.  ``close()`` is idempotent
    and joins the serving thread.
    """

    def __init__(self, telemetry: Telemetry, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.telemetry = telemetry
        handler = _make_handler(telemetry)
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="telemetry-http",
            kwargs={"poll_interval": 0.2}, daemon=True,
        )
        self._thread.start()
        self._closed = False

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()


def _make_handler(telemetry: Telemetry) -> type:
    class Handler(BaseHTTPRequestHandler):
        # The endpoint must never spam the host process's stderr.
        def log_message(self, fmt: str, *args) -> None:  # pragma: no cover
            pass

        def _reply(self, status: int, body: bytes,
                   content_type: str) -> None:
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(body)))
            self.send_header("Cache-Control", "no-store")
            self.end_headers()
            self.wfile.write(body)

        def _json(self, obj, status: int = 200) -> None:
            body = json.dumps(obj, default=str, indent=1).encode("utf-8")
            self._reply(status, body, "application/json; charset=utf-8")

        def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
            try:
                split = urlsplit(self.path)
                path = split.path.rstrip("/") or "/"
                query = parse_qs(split.query)
                if path == "/":
                    self._reply(200, DASHBOARD_HTML.encode("utf-8"),
                                "text/html; charset=utf-8")
                elif path == "/metrics":
                    fmt = (query.get("format") or ["json"])[0]
                    if fmt == "prom":
                        self._reply(
                            200, telemetry.prometheus().encode("utf-8"),
                            "text/plain; version=0.0.4; charset=utf-8",
                        )
                    elif fmt == "json":
                        self._json(telemetry.snapshot())
                    else:
                        self._json(
                            {"error": f"unknown format {fmt!r} "
                                      "(expected json or prom)"},
                            status=400,
                        )
                elif path == "/jobs":
                    self._json({"jobs": telemetry.snapshot()["jobs"]})
                elif path == "/nodes":
                    self._json({"nodes": telemetry.snapshot()["nodes"]})
                elif path == "/events":
                    try:
                        since = int((query.get("since") or ["0"])[0])
                        limit = int((query.get("limit") or ["500"])[0])
                    except ValueError:
                        self._json(
                            {"error": "since/limit must be integers"},
                            status=400,
                        )
                        return
                    events = telemetry.events_since(since, limit)
                    next_cursor = events[-1]["seq"] if events else since
                    self._json({"events": events, "next": next_cursor})
                else:
                    self._json({"error": f"no such route {path!r}"},
                               status=404)
            except (BrokenPipeError, ConnectionResetError):
                pass  # scraper went away mid-reply; nothing to clean up

    return Handler
