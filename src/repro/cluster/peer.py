"""Peer data plane: direct node→node stage forwarding + broadcast blocks.

The paper's Host–Node topology relays every stage-to-stage byte through the
host, so the host NIC is the throughput ceiling for multi-stage pipelines.
This module decentralises the *data* plane while the host keeps the whole
*control* plane — placement, credits, liveness, and the exactly-once ledger:

* Every node-loader opens one listening :class:`PeerServer` socket and
  reports its port in REGISTER.  The host ships a **peer directory**
  (``node_id -> (ip, port)``) and, per job, a **routing table** (source
  stage ``s`` -> ordered target nodes for the ``s -> s+1`` hop) inside the
  LOAD payload.
* For a hop marked ``route="peer"`` a stage-``s`` node ships its results
  *directly* to a stage-``s+1`` node as a ``PEER_ITEMS`` frame (placement:
  round-robin, or ``key_fn``-keyed partition — a keyed shuffle for free)
  and tells the host what it did with a compact ``ITEM_ACK`` (ids only).
  The host records the forwarded item in its peer-inflight ledger so a
  dead receiver's stranded items are re-dispatched, and duplicate results
  are dropped by the same per-stage dedup that covers host-routed hops.
* On the same sockets rides a chunked **broadcast block** layer: the host
  publishes named immutable blobs (``ClusterService.publish_block``),
  nodes stripe their first fetch across the host (each node pulls a
  disjoint ``1/n`` of the chunks) and trade the remaining chunks with each
  other, so an N-node pool costs the host ~1 copy instead of N.  Complete
  blocks are LRU-bounded like the warm code cache; work functions read
  them via :func:`get_block`.

Failure semantics: a peer send tries every routing-table target in
preference order and falls back to the ordinary host-relayed RESULT_BATCH
when no peer is reachable — peer routing is an optimisation, never a
correctness dependency.  The chaos harness cuts edges via
:func:`partition_node` (module-level seam, effective under the in-process
launcher where all node threads share this module).
"""

from __future__ import annotations

import hashlib
import socket
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Iterable

from repro.cluster.netchannels import ChannelClosed
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    BLOCK_CACHE_SLOTS,
    BLOCK_CHUNK_BYTES,
    Frame,
    FrameConnection,
    FrameType,
    loads_code,
    pack_frame_buffers,
    _buffers_len,
)

__all__ = [
    "BlockRegistry", "BlockStore", "PeerClient", "PeerServer", "RouteTable",
    "block_digest", "fetch_blocks", "get_block", "heal_partitions",
    "partition_node", "stable_hash",
]

# How long a dialed peer link waits on connect and on a chunk reply before
# the link is declared dead and the caller falls back (next target / host).
PEER_DIAL_TIMEOUT_S = 5.0
PEER_IO_TIMEOUT_S = 10.0


# ---------------------------------------------------------------------------
# Chaos seam: partitioned peer edges
# ---------------------------------------------------------------------------

_partition_lock = threading.Lock()
_partitioned_until: dict[str, float] = {}


def partition_node(node_id: str, duration_s: float = 1.0) -> None:
    """Cut every peer edge touching ``node_id`` for ``duration_s``.

    Module-level on purpose: under the in-process launcher all node threads
    share this module, so the chaos controller (host side) can sever edges
    the node-loaders will honour.  Subprocess pools do not see it — the
    chaos fault documents that limitation.

    The cut is enforced on the *send* side only (``PeerClient._link``
    checks both endpoints before every transfer).  Item frames already in
    flight when the partition activates are still processed by the
    receiver: the sender has told the host the transfer succeeded, so a
    receiver-side drop would strand the item in the exactly-once ledger
    at a live target and stall the job to its deadline.  Block chunk
    *requests* answer ``data=None`` under a partition instead — the
    fetcher treats that as a miss and retries elsewhere, so the stricter
    behaviour is safe there.
    """
    with _partition_lock:
        _partitioned_until[node_id] = time.monotonic() + duration_s


def heal_partitions() -> None:
    with _partition_lock:
        _partitioned_until.clear()


def is_partitioned(*node_ids: str | None) -> bool:
    now = time.monotonic()
    with _partition_lock:
        return any(
            nid is not None and _partitioned_until.get(nid, 0.0) > now
            for nid in node_ids
        )


# ---------------------------------------------------------------------------
# Stable hashing (keyed partition must agree across processes)
# ---------------------------------------------------------------------------


def stable_hash(key: Any) -> int:
    """A process-independent 64-bit hash for keyed partitioning.

    Python's builtin ``hash`` is salted per process (PYTHONHASHSEED), so two
    nodes would disagree on ``hash(key) % n``; this one is stable across
    processes, runs, and machines for the common key types.
    """
    return int.from_bytes(
        hashlib.sha256(_hash_bytes(key)).digest()[:8], "big"
    )


def _hash_bytes(key: Any) -> bytes:
    if isinstance(key, bytes):
        return b"b:" + key
    if isinstance(key, str):
        return b"s:" + key.encode("utf-8", "surrogatepass")
    if isinstance(key, bool):
        return b"B:1" if key else b"B:0"
    if isinstance(key, int):
        return b"i:%d" % key
    if isinstance(key, float):
        return b"f:" + repr(key).encode()
    if key is None:
        return b"n:"
    if isinstance(key, (tuple, list)):
        return b"t:" + b",".join(_hash_bytes(k) for k in key)
    return b"r:" + repr(key).encode("utf-8", "backslashreplace")


# ---------------------------------------------------------------------------
# Routing tables (node side; built by the host, shipped in LOAD)
# ---------------------------------------------------------------------------


class RouteTable:
    """Per-job peer routing: source stage ``s`` -> hop placement.

    ``raw`` is the host's wire form: ``{str(s): {"targets": [node_id...],
    "mode": "rr"|"keyed", "key_fn": code-blob|None}}``.  ``targets_for``
    returns the full target list in *preference order* — the sender walks
    it until a send succeeds, then falls back to the host, so a stale
    table (dead target, healed replacement not listed) degrades instead of
    failing.  Keyed mode pins the first preference by ``stable_hash(
    key_fn(value))``; under a dead primary the key rehashes to the next
    target — placement is best-effort, correctness never depends on it.
    """

    def __init__(self, raw: dict):
        self._lock = threading.Lock()
        self._entries: dict[int, dict] = {}
        for s, ent in (raw or {}).items():
            blob = ent.get("key_fn")
            self._entries[int(s)] = {
                "targets": list(ent.get("targets") or []),
                "key_fn": loads_code(blob) if blob else None,
                "rr": 0,
            }

    def stages(self) -> set[int]:
        return set(self._entries)

    def has(self, s: int) -> bool:
        return s in self._entries and bool(self._entries[s]["targets"])

    def targets_for(self, s: int, value: Any) -> list[str]:
        ent = self._entries.get(s)
        if ent is None or not ent["targets"]:
            return []
        targets = ent["targets"]
        if ent["key_fn"] is not None:
            first = stable_hash(ent["key_fn"](value)) % len(targets)
        else:
            with self._lock:
                first = ent["rr"] % len(targets)
                ent["rr"] += 1
        return [targets[(first + k) % len(targets)] for k in range(len(targets))]


# ---------------------------------------------------------------------------
# Broadcast blocks
# ---------------------------------------------------------------------------


def block_digest(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def _nchunks(size: int) -> int:
    return max(1, -(-size // BLOCK_CHUNK_BYTES))


class BlockRegistry:
    """Host-side store of published blocks (the origin copy).

    ``publish`` is idempotent for identical bytes; re-publishing a name
    with different content raises — blocks are immutable by contract (the
    digest in the manifest is what nodes verify against).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._blocks: dict[str, bytes] = {}
        self._meta: dict[str, dict] = {}
        self.chunks_served = 0
        self.chunk_bytes_served = 0

    def publish(self, name: str, data: bytes) -> str:
        if not isinstance(data, (bytes, bytearray, memoryview)):
            raise TypeError(f"block {name!r} must be bytes, got {type(data)}")
        data = bytes(data)
        digest = block_digest(data)
        with self._lock:
            prior = self._meta.get(name)
            if prior is not None and prior["digest"] != digest:
                raise ValueError(
                    f"block {name!r} already published with different content"
                )
            self._blocks[name] = data
            self._meta[name] = {
                "name": name, "digest": digest, "size": len(data),
                "nchunks": _nchunks(len(data)),
            }
        return digest

    def manifest(self) -> list[dict]:
        with self._lock:
            return [dict(m) for m in self._meta.values()]

    def get_chunk(self, name: str, idx: int) -> bytes | None:
        with self._lock:
            data = self._blocks.get(name)
            if data is None:
                return None
            lo = idx * BLOCK_CHUNK_BYTES
            if idx < 0 or lo >= len(data) and not (idx == 0 and not data):
                return None
            chunk = data[lo:lo + BLOCK_CHUNK_BYTES]
            self.chunks_served += 1
            self.chunk_bytes_served += len(chunk)
            return chunk


# Process-global published blocks: the read side for work functions.  Under
# the in-process launcher every node thread shares this dict — harmless,
# since blocks are immutable and digest-verified.  Entries are refcounted
# by the BlockStores holding the block resident, and evicted when the last
# holder's LRU lets go, so this mirror is bounded by the stores' slots and
# a long-lived warm pool node does not retain every block ever published.
_global_cv = threading.Condition()
_global_blocks: dict[str, bytes] = {}
_global_refs: dict[str, int] = {}


def get_block(name: str, timeout: float = 60.0) -> bytes:
    """Read a published broadcast block from inside a work function.

    Blocks are fetched at LOAD time; the wait only triggers when a work
    item races ahead of a still-assembling block.
    """
    deadline = time.monotonic() + timeout
    with _global_cv:
        while name not in _global_blocks:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise KeyError(f"block {name!r} not available on this node")
            _global_cv.wait(remaining)
        return _global_blocks[name]


def _publish_global(name: str, data: bytes) -> None:
    with _global_cv:
        _global_blocks[name] = data
        _global_refs[name] = _global_refs.get(name, 0) + 1
        _global_cv.notify_all()


def _unpublish_global(name: str) -> None:
    """One holder evicted/released the block; drop the global copy when
    the last holder is gone."""
    with _global_cv:
        refs = _global_refs.get(name, 0) - 1
        if refs > 0:
            _global_refs[name] = refs
        else:
            _global_refs.pop(name, None)
            _global_blocks.pop(name, None)


class BlockStore:
    """Node-side chunk assembly + LRU-bounded complete blocks.

    Chunks arrive from two directions (host replies routed by the frame
    loop, synchronous peer fetches) and are idempotent; a completed block
    is digest-verified before it becomes readable, a corrupt assembly is
    dropped so the fetcher retries from the host.
    """

    def __init__(self, slots: int = BLOCK_CACHE_SLOTS):
        self._cv = threading.Condition()
        self._slots = slots
        self._blocks: OrderedDict[str, bytes] = OrderedDict()
        self._meta: dict[str, dict] = {}
        self._partial: dict[str, dict[int, bytes]] = {}
        self.fetched_from_peers = 0
        self.fetched_from_host = 0
        self.chunks_served = 0
        self.digest_failures = 0

    def expect(self, entry: dict) -> bool:
        """Register a manifest entry; True when the block still needs
        fetching on this node."""
        name = entry["name"]
        with self._cv:
            if name in self._blocks and (
                self._meta[name]["digest"] == entry["digest"]
            ):
                self._blocks.move_to_end(name)
                return False
            self._meta[name] = dict(entry)
            self._partial.setdefault(name, {})
            return True

    def missing(self, name: str) -> list[int]:
        with self._cv:
            meta = self._meta.get(name)
            if meta is None or name in self._blocks:
                return []
            have = self._partial.get(name) or {}
            return [c for c in range(meta["nchunks"]) if c not in have]

    def add_chunk(self, name: str, idx: int, data: bytes | None,
                  *, from_peer: bool = False) -> None:
        if data is None:
            return
        with self._cv:
            meta = self._meta.get(name)
            if meta is None or name in self._blocks:
                return
            part = self._partial.setdefault(name, {})
            if idx in part or not (0 <= idx < meta["nchunks"]):
                return
            part[idx] = bytes(data)
            if from_peer:
                self.fetched_from_peers += 1
            else:
                self.fetched_from_host += 1
            if len(part) < meta["nchunks"]:
                return
            blob = b"".join(part[c] for c in range(meta["nchunks"]))
            if block_digest(blob) != meta["digest"] or len(blob) != meta["size"]:
                self.digest_failures += 1
                self._partial[name] = {}
                return
            self._partial.pop(name, None)
            self._blocks[name] = blob
            evicted = []
            while len(self._blocks) > self._slots:
                old, _ = self._blocks.popitem(last=False)
                self._meta.pop(old, None)
                evicted.append(old)
            _publish_global(name, blob)
            # The global read-side mirror must shrink with the LRU or an
            # immortal pool node retains every block ever published.
            for old in evicted:
                _unpublish_global(old)
            self._cv.notify_all()

    def get_chunk(self, name: str, idx: int) -> bytes | None:
        """Serve a chunk to a peer — from a complete block or a partial
        assembly (striped chunks propagate before the block completes)."""
        with self._cv:
            data = self._blocks.get(name)
            if data is not None:
                self._blocks.move_to_end(name)
                lo = idx * BLOCK_CHUNK_BYTES
                if idx < 0 or (lo >= len(data) and not (idx == 0 and not data)):
                    return None
                self.chunks_served += 1
                return data[lo:lo + BLOCK_CHUNK_BYTES]
            chunk = (self._partial.get(name) or {}).get(idx)
            if chunk is not None:
                self.chunks_served += 1
            return chunk

    def has(self, name: str) -> bool:
        with self._cv:
            return name in self._blocks

    def wait(self, name: str, timeout: float = 60.0) -> bytes:
        deadline = time.monotonic() + timeout
        with self._cv:
            while name not in self._blocks:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"block {name!r} incomplete after {timeout}s")
                self._cv.wait(remaining)
            return self._blocks[name]

    def counters(self) -> dict[str, int]:
        with self._cv:
            return {
                "blocks_fetched_from_peers": self.fetched_from_peers,
                "blocks_fetched_from_host": self.fetched_from_host,
                "block_chunks_served": self.chunks_served,
                "blocks_resident": len(self._blocks),
            }

    def release(self) -> None:
        """Drop every resident block and its global refcounts — node
        shutdown; without this an in-process pool's exited nodes would
        pin their blocks in the process-global mirror forever."""
        with self._cv:
            names, self._blocks = list(self._blocks), OrderedDict()
            self._meta.clear()
            self._partial.clear()
        for name in names:
            _unpublish_global(name)


# ---------------------------------------------------------------------------
# Peer links (dial side)
# ---------------------------------------------------------------------------


class _PeerLink:
    """One dialed data-plane connection to a sibling node.

    Sends (PEER_ITEMS) never expect a reply; the only request/response pair
    is BLOCK_REQUEST -> BLOCK_CHUNK, serialised under ``_req_lock`` so the
    single ``recv`` always reads its own reply (the server answers frames
    in arrival order).
    """

    def __init__(self, conn: FrameConnection):
        self.conn = conn
        self._req_lock = threading.Lock()
        self.alive = True

    def send_items(self, job_id: int, sender: str, items: list[dict]) -> int:
        frame = Frame(FrameType.PEER_ITEMS, {"from": sender, "items": items},
                      APP_WIRE_CHANNEL, job_id)
        bufs = pack_frame_buffers(frame)
        nbytes = _buffers_len(bufs)
        self.conn.send_raw(bufs)
        return nbytes

    def fetch_chunk(self, name: str, idx: int) -> bytes | None:
        with self._req_lock:
            self.conn.send(Frame(FrameType.BLOCK_REQUEST,
                                 {"name": name, "chunk": idx}))
            reply = self.conn.recv()
        if reply.ftype is not FrameType.BLOCK_CHUNK:
            raise ChannelClosed(f"unexpected {reply.ftype.name} on peer link")
        return reply.payload.get("data")

    def close(self) -> None:
        self.alive = False
        self.conn.close()


class PeerClient:
    """Dial-and-cache peer links, keyed by target node id.

    ``directory`` is the live ``node_id -> (ip, port)`` map owned by the
    node-loader (merged from every LOAD); the client resolves targets at
    send time so directory refreshes take effect without reconnecting.
    """

    def __init__(self, node_id: str, directory: dict[str, tuple[str, int]]):
        self.node_id = node_id
        self.directory = directory
        self._links: dict[str, _PeerLink] = {}
        self._lock = threading.Lock()
        self.items_sent = 0
        self.bytes_sent = 0

    def _link(self, target: str) -> _PeerLink:
        if is_partitioned(self.node_id, target):
            raise ChannelClosed(
                f"peer edge {self.node_id}->{target} partitioned")
        with self._lock:
            link = self._links.get(target)
        if link is not None and link.alive:
            return link
        addr = self.directory.get(target)
        if not addr:
            raise ChannelClosed(f"no peer address for {target!r}")
        host, port = addr[0], int(addr[1])
        try:
            sock = socket.create_connection((host, port),
                                            timeout=PEER_DIAL_TIMEOUT_S)
        except OSError as exc:
            raise ChannelClosed(f"dial {target} ({host}:{port}): {exc}") from exc
        sock.settimeout(PEER_IO_TIMEOUT_S)
        link = _PeerLink(FrameConnection(sock))
        try:
            link.conn.send(Frame(FrameType.PEER_HELLO,
                                 {"node_id": self.node_id}))
        except OSError as exc:
            link.close()
            raise ChannelClosed(f"hello to {target}: {exc}") from exc
        with self._lock:
            prior = self._links.get(target)
            if prior is not None and prior.alive:
                link.close()
                return prior
            self._links[target] = link
        return link

    def _drop(self, target: str) -> None:
        with self._lock:
            link = self._links.pop(target, None)
        if link is not None:
            link.close()

    def send_items(self, job_id: int, target: str, items: list[dict]) -> int:
        """Ship result items to ``target``; returns bytes on the wire.
        Raises :class:`ChannelClosed` when the edge is unusable."""
        link = self._link(target)
        try:
            nbytes = link.send_items(job_id, self.node_id, items)
        except (OSError, ValueError) as exc:
            self._drop(target)
            raise ChannelClosed(f"send to {target}: {exc}") from exc
        self.items_sent += len(items)
        self.bytes_sent += nbytes
        return nbytes

    def fetch_chunk(self, target: str, name: str, idx: int) -> bytes | None:
        """Fetch one block chunk from a peer; None means the peer does not
        have it yet.  Raises :class:`ChannelClosed` on a dead edge."""
        link = self._link(target)
        try:
            return link.fetch_chunk(name, idx)
        except (OSError, ChannelClosed, ValueError) as exc:
            self._drop(target)
            if isinstance(exc, ChannelClosed):
                raise
            raise ChannelClosed(f"fetch from {target}: {exc}") from exc

    def close(self) -> None:
        with self._lock:
            links, self._links = list(self._links.values()), {}
        for link in links:
            link.close()


# ---------------------------------------------------------------------------
# Peer server (listen side)
# ---------------------------------------------------------------------------


class PeerServer:
    """A node's listening data-plane socket.

    One accept thread; one reader thread per accepted connection, handling
    PEER_HELLO (identify sender), PEER_ITEMS (hand work to the node-loader
    via ``on_items``) and BLOCK_REQUEST (serve a chunk from the local
    store).  Items arriving before the node-loader has installed its
    handler are held and drained on :meth:`set_on_items` — a sibling's
    LOAD can complete before ours.
    """

    def __init__(self, node_id: str, block_store: BlockStore,
                 bind_host: str = "0.0.0.0"):
        self.node_id = node_id
        self.block_store = block_store
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((bind_host, 0))
        self._sock.listen(64)
        self.port = self._sock.getsockname()[1]
        self._lock = threading.Lock()
        self._on_items: Callable[[int, list], None] | None = None
        self._intake_gate: Callable[[int], None] | None = None
        self._held: list[tuple[int, list]] = []
        self._conns: list[FrameConnection] = []
        self._closed = False
        self.items_recv = 0
        self.bytes_recv = 0

    def set_on_items(self, fn: Callable[[int, list], None]) -> None:
        with self._lock:
            self._on_items = fn
            held, self._held = self._held, []
        for job_id, items in held:
            fn(job_id, items)

    def set_intake_gate(self, gate: Callable[[int], None]) -> None:
        """Install a backpressure gate called (with the item count) on the
        reader thread before each PEER_ITEMS batch is handed over.  A gate
        that blocks while the node's peer backlog is full stops the socket
        drain, so the kernel buffers fill and TCP throttles the sender —
        the peer plane's analogue of the host's credit window."""
        with self._lock:
            self._intake_gate = gate

    def start(self) -> None:
        threading.Thread(target=self._accept_loop,
                         name=f"peer-accept-{self.node_id}",
                         daemon=True).start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve, args=(conn,),
                             name=f"peer-serve-{self.node_id}",
                             daemon=True).start()

    def _serve(self, conn: FrameConnection) -> None:
        sender: str | None = None
        try:
            while True:
                frame = conn.recv()
                if frame.ftype is FrameType.PEER_HELLO:
                    sender = frame.payload.get("node_id")
                elif frame.ftype is FrameType.PEER_ITEMS:
                    items = frame.payload.get("items") or []
                    # No partition check here: the SENDER gates every
                    # transfer on is_partitioned (in ``_link``), so a
                    # frame that reached us was sent before the edge was
                    # cut and must be processed — eating it would strand
                    # the item in the host's exactly-once ledger at a
                    # live target, which no requeue path ever revisits.
                    self.items_recv += len(items)
                    with self._lock:
                        handler = self._on_items
                        gate = self._intake_gate
                        if handler is None:
                            self._held.append((frame.job_id, items))
                    if handler is not None:
                        if gate is not None:
                            gate(len(items))
                        handler(frame.job_id, items)
                elif frame.ftype is FrameType.BLOCK_REQUEST:
                    name = frame.payload.get("name")
                    idx = int(frame.payload.get("chunk", 0))
                    data = None
                    if not is_partitioned(self.node_id, sender):
                        data = self.block_store.get_chunk(name, idx)
                    conn.send(Frame(FrameType.BLOCK_CHUNK,
                                    {"name": name, "chunk": idx, "data": data}))
        except (ConnectionError, OSError, ValueError):
            pass
        finally:
            self.bytes_recv += conn.counters.bytes_recv
            conn.close()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)

    def counters(self) -> dict[str, int]:
        with self._lock:
            live = sum(c.counters.bytes_recv for c in self._conns)
        return {
            "peer_items_recv": self.items_recv,
            "peer_bytes_recv": self.bytes_recv + live,
        }

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = list(self._conns), []
        for conn in conns:
            conn.close()


# ---------------------------------------------------------------------------
# Block fetch orchestration (runs on a node-loader thread at LOAD time)
# ---------------------------------------------------------------------------


def fetch_blocks(manifest: Iterable[dict], *, store: BlockStore,
                 client: PeerClient, host_request: Callable[[str, int], None],
                 deadline_s: float = 60.0) -> None:
    """Assemble every manifest block: stripe the host, trade with peers.

    With ``n`` nodes (sorted directory order), node ``i`` pulls chunks
    ``c % n == i`` from the host (async — replies come back through the
    node's frame loop into ``store.add_chunk``) and asks peers for the
    rest, retrying with backoff; any chunk still missing near the deadline
    is re-requested from the host, so a lone node or a partitioned pool
    still converges.
    """
    todo = [dict(m) for m in manifest if store.expect(m)]
    if not todo:
        return
    peers = sorted(n for n in client.directory if n != client.node_id)
    ring = sorted(set(client.directory) | {client.node_id})
    n = max(1, len(ring))
    my_index = ring.index(client.node_id) if client.node_id in ring else 0
    deadline = time.monotonic() + deadline_s
    for meta in todo:
        for c in range(meta["nchunks"]):
            if c % n == my_index:
                host_request(meta["name"], c)
    backoff = 0.02
    while time.monotonic() < deadline:
        remaining = [m for m in todo if store.missing(m["name"])]
        if not remaining:
            return
        progressed = False
        for meta in remaining:
            name = meta["name"]
            for c in store.missing(name):
                if c % n == my_index:
                    continue  # the host reply is in flight
                for k in range(len(peers)):
                    target = peers[(my_index + 1 + k + c) % len(peers)] if peers else None
                    if target is None:
                        break
                    try:
                        data = client.fetch_chunk(target, name, c)
                    except ChannelClosed:
                        continue
                    if data is not None:
                        store.add_chunk(name, c, data, from_peer=True)
                        progressed = True
                        break
        if progressed:
            backoff = 0.02
            continue
        # Peers have nothing new for us yet; near the deadline, stop being
        # polite and pull the stragglers straight from the origin.
        if deadline - time.monotonic() < deadline_s / 2:
            for meta in remaining:
                for c in store.missing(meta["name"]):
                    host_request(meta["name"], c)
            for meta in remaining:
                try:
                    store.wait(meta["name"],
                               max(0.05, deadline - time.monotonic()))
                except TimeoutError:
                    pass
            return
        time.sleep(backoff)
        backoff = min(backoff * 2, 0.25)
