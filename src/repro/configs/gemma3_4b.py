"""gemma3-4b [dense] — 5:1 local:global attention, 128k context
(hf:google/gemma-3-1b-pt family; unverified).  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  head_dim=256 (decoupled from d_model/H, as in the
official config).  The 5-of-6 sliding-window layers make decode state O(1)
for most of the stack, so gemma3 runs long_500k (global layers keep the full
cache, sharded over the mesh)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    use_qk_norm=True,
    supports_long_context=True,
)
