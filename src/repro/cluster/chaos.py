"""Chaos-injection harness for the *real* multi-process transport.

The SPMD executor already injects simulated failures (``runtime.failures
.FailurePlan``); this module attacks the actual wire protocol — sockets,
frames, heartbeats, node processes — so the detect → requeue → heal →
retry machinery is exercised continuously instead of assumed.  The paper
claims the generated architecture is "free from deadlock and livelock" on
failure-prone workstations; a :class:`FaultPlan` is how we keep earning
that claim on every run.

Three layers:

* :class:`Fault` / :class:`FaultPlan` — a declarative list of timed
  (``at_s``) or progress-conditioned (``after_items``) faults.  Kinds:

  - ``kill_node`` — hard-kill one node mid-job through the deployment
    layer (real death: heartbeats stop, the host reaps and heals);
  - ``drop`` — discard matching frames (defaults to HEARTBEAT: data
    frames on a live TCP stream are delivered exactly once by the
    transport, so dropping them is *unrecoverable by design* — recovery
    always flows through death detection);
  - ``delay`` / ``straggler`` — hold each matching inbound frame for
    ``delay_s`` (a slow workstation, seen from the host's side);
  - ``duplicate`` — deliver matching inbound frames twice (exercises the
    result-id dedup that exactly-once collection rests on);
  - ``stall_heartbeat`` — drop the node's beats only: the host declares a
    perfectly healthy node dead and its late results arrive as zombie
    duplicates;
  - ``partition`` — drop *everything* both ways for ``duration_s``
    (choose it >= the heartbeat deadline so the death path can recover);
  - ``corrupt`` — rewrite the codec byte of an outbound frame so the
    node's ``decode_payload`` raises (the decode-error death path).

* :class:`WireFaults` + :class:`FaultyConnection` — an injectable wrapper
  over :class:`~repro.cluster.wire.FrameConnection` (duck-compatible with
  it and with :class:`~repro.cluster.netchannels.ChannelMux`'s ``conn``)
  that applies the active wire rules on the host's per-node connections.
  ``FaultyChannel`` is an alias.  Drop/delay/duplicate act on the *recv*
  path (each node has its own reader thread, so a sleep there slows only
  that node); corrupt acts on *send* (the bytes must be damaged before
  the node decodes them).

* :class:`ChaosController` — owns the plan: a poll thread fires each
  fault at its trigger, turning it into a node kill (via the injected
  ``kill`` callback) or a wire rule with an expiry.  Every injection is
  published on the telemetry bus (``chaos_inject`` events, a
  ``faults_injected`` counter, and a ``chaos`` snapshot section).

Plug in via ``ClusterService(chaos=plan)`` or
``ProcessClusterApplication(chaos=plan)``; tests and the CI chaos-smoke
bench drive it hermetically over the InProcessLauncher.
"""

from __future__ import annotations

import collections
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

from repro.cluster.wire import (
    Frame,
    FrameConnection,
    FrameType,
    pack_frame_buffers,
)

__all__ = [
    "FAULT_KINDS",
    "Fault",
    "FaultPlan",
    "WireFaults",
    "FaultyConnection",
    "FaultyChannel",
    "ChaosController",
]

FAULT_KINDS = (
    "kill_node",
    "drop",
    "delay",
    "duplicate",
    "corrupt",
    "stall_heartbeat",
    "partition",
    "partition_peer",
    "straggler",
)

# Which wire frames a fault touches when the user does not say.  Chosen so
# every default is *recoverable*: heartbeat loss and duplication both heal
# through the death-detection / dedup paths, and a corrupt WORK_BATCH
# kills its node (decode error), which the host reaps like any crash.
_DEFAULT_FRAME_TYPES: dict[str, tuple[str, ...]] = {
    "drop": ("HEARTBEAT",),
    "duplicate": ("RESULT_BATCH", "RESULT"),
    "corrupt": ("WORK_BATCH",),
    "stall_heartbeat": ("HEARTBEAT",),
}

# An invalid codec id: the receiver's decode_payload raises ValueError
# ("unknown payload codec") while the stream framing stays aligned — the
# corruption is detected at the protocol layer, not as a hung socket.
_CORRUPT_CODEC = 0x7F
_CODEC_BYTE_OFFSET = 6  # _HEADER = "!4sBBBBII": magic(4) ver ftype codec ...


@dataclass
class Fault:
    """One declarative fault.  ``node=None`` matches every node (wire
    faults only; ``kill_node`` must name its victim).

    Triggers: ``after_items`` fires once the cluster has collected that
    many items (progress-conditioned — "mid-job" without guessing wall
    time); otherwise ``at_s`` fires that many seconds after the
    controller is armed.  ``duration_s=None`` means the wire rule never
    expires; ``count`` caps how many frames it touches; ``probability``
    makes it flaky rather than total.
    """

    kind: str
    node: str | None = None
    at_s: float = 0.0
    after_items: int | None = None
    duration_s: float | None = None
    probability: float = 1.0
    delay_s: float = 0.05
    frame_types: tuple[str, ...] = ()
    count: int | None = None

    def validate(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}"
            )
        if self.kind in ("kill_node", "partition_peer") and not self.node:
            raise ValueError(f"{self.kind} faults must name their node=")
        if not (0.0 < self.probability <= 1.0):
            raise ValueError(
                f"probability must be in (0, 1], got {self.probability}"
            )
        if self.delay_s < 0 or self.at_s < 0:
            raise ValueError("at_s and delay_s must be >= 0")
        if self.count is not None and self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")
        for name in self.frame_types:
            if name not in FrameType.__members__:
                raise ValueError(f"unknown frame type {name!r}")

    def resolved_frame_types(self) -> frozenset[FrameType] | None:
        """The FrameType filter this fault's wire rule applies (None =
        all frames)."""
        names = self.frame_types or _DEFAULT_FRAME_TYPES.get(self.kind, ())
        if not names:
            return None
        return frozenset(FrameType[name] for name in names)


@dataclass
class FaultPlan:
    """A deterministic (seeded) schedule of faults for one run."""

    faults: list[Fault] = field(default_factory=list)
    seed: int = 0

    def validate(self) -> None:
        for fault in self.faults:
            fault.validate()


class _WireRule:
    """One active wire-level fault: which frames of which node get which
    treatment, until expiry / count exhaustion."""

    def __init__(self, fault: Fault, action: str, direction: str,
                 expires_at: float | None):
        self.fault = fault
        self.action = action  # "drop" | "delay" | "duplicate" | "corrupt"
        self.direction = direction  # "recv" (node->host) | "send" (host->node)
        self.ftypes = fault.resolved_frame_types()
        self.expires_at = expires_at
        self.remaining = fault.count  # None = unbounded
        self.hits = 0

    def expired(self, now: float) -> bool:
        if self.remaining is not None and self.remaining <= 0:
            return True
        return self.expires_at is not None and now >= self.expires_at

    def matches(self, node_id: str | None, direction: str,
                frame: Frame) -> bool:
        if direction != self.direction:
            return False
        if self.fault.node is not None and self.fault.node != node_id:
            return False
        if self.ftypes is not None and frame.ftype not in self.ftypes:
            return False
        return True


class WireFaults:
    """Thread-safe registry of active wire rules.

    Consulted by every :class:`FaultyConnection` on both frame paths; the
    controller installs rules when faults fire and they expire lazily
    here (no rule-removal thread needed).
    """

    def __init__(self, rng: random.Random | None = None):
        self._rules: list[_WireRule] = []
        self._rng = rng or random.Random()
        self._lock = threading.Lock()

    def install(self, rule: _WireRule) -> None:
        with self._lock:
            self._rules.append(rule)

    def active_count(self) -> int:
        now = time.monotonic()
        with self._lock:
            self._rules = [r for r in self._rules if not r.expired(now)]
            return len(self._rules)

    def match(self, node_id: str | None, direction: str,
              frame: Frame) -> _WireRule | None:
        """The first live rule touching this frame (consuming one of its
        ``count`` and rolling its ``probability`` die), or None."""
        now = time.monotonic()
        with self._lock:
            self._rules = [r for r in self._rules if not r.expired(now)]
            for rule in self._rules:
                if not rule.matches(node_id, direction, frame):
                    continue
                if (rule.fault.probability < 1.0
                        and self._rng.random() >= rule.fault.probability):
                    continue
                if rule.remaining is not None:
                    rule.remaining -= 1
                rule.hits += 1
                return rule
        return None


class FaultyConnection:
    """An injectable proxy over one :class:`FrameConnection`.

    Installed by the host's accept loop (``conn_wrapper=``), so *every*
    frame of that node crosses the fault registry.  The wrapped node's
    identity is learned from its REGISTER frame — rules that name a node
    only start matching once it has introduced itself.
    """

    def __init__(self, conn: FrameConnection, faults: WireFaults,
                 node_id: str | None = None):
        self._conn = conn
        self._faults = faults
        self.node_id = node_id
        self._pending: collections.deque[Frame] = collections.deque()

    # -- passthrough surface (everything HostLoader/ChannelMux touches) -----

    @property
    def sock(self):
        return self._conn.sock

    @property
    def counters(self):
        return self._conn.counters

    @property
    def peer(self) -> str:
        return self._conn.peer

    def close(self) -> None:
        self._conn.close()

    # -- the faulted frame paths --------------------------------------------

    def send(self, frame: Frame) -> None:
        rule = self._faults.match(self.node_id, "send", frame)
        if rule is None:
            self._conn.send(frame)
            return
        if rule.action == "drop":
            return  # swallowed: the peer simply never hears it
        if rule.action == "corrupt":
            bufs = pack_frame_buffers(frame)
            header = bytearray(bufs[0])
            header[_CODEC_BYTE_OFFSET] = _CORRUPT_CODEC
            self._conn.send_raw([bytes(header), *bufs[1:]])
            return
        if rule.action == "duplicate":
            self._conn.send(frame)
        self._conn.send(frame)

    def recv(self) -> Frame:
        while True:
            if self._pending:
                return self._pending.popleft()
            frame = self._conn.recv()
            if self.node_id is None and frame.ftype is FrameType.REGISTER:
                self.node_id = (frame.payload or {}).get("node_id")
            rule = self._faults.match(self.node_id, "recv", frame)
            if rule is None:
                return frame
            if rule.action == "drop":
                continue  # the host never sees it
            if rule.action == "delay":
                # Sleeping here stalls only this node's reader thread —
                # the dispatcher and every other node keep their pace.
                time.sleep(rule.fault.delay_s)
                return frame
            if rule.action == "duplicate":
                self._pending.append(frame)
                return frame
            return frame


#: The ISSUE's name for the netchannels-layer wrapper; same object.
FaultyChannel = FaultyConnection


class ChaosController:
    """Arms a :class:`FaultPlan` against a live cluster.

    ``kill`` is the deployment-layer callback (``kill(node_id) -> bool``);
    ``items_fn`` reports cluster progress for ``after_items`` triggers;
    ``telemetry`` receives one ``chaos_inject`` event per fired fault.
    ``wrap_connection`` is handed to the host's accept loop so wire rules
    reach every node connection.
    """

    POLL_S = 0.005

    def __init__(self, plan: FaultPlan, *,
                 kill: Callable[[str], Any] | None = None,
                 telemetry: Any = None,
                 items_fn: Callable[[], int] | None = None):
        plan.validate()
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self.wire = WireFaults(self._rng)
        self._kill = kill
        self.telemetry = telemetry
        self._items_fn = items_fn
        self.fired: list[dict] = []
        self.injected = 0
        self._armed_at: float | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # -- plumbing ------------------------------------------------------------

    def wrap_connection(self, conn: FrameConnection) -> FaultyConnection:
        return FaultyConnection(conn, self.wire)

    @property
    def armed(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def arm(self) -> None:
        """Start the trigger clock; idempotent."""
        if self.armed:
            return
        self._stop.clear()
        self._armed_at = time.monotonic()
        self._thread = threading.Thread(target=self._loop, name="chaos",
                                        daemon=True)
        self._thread.start()

    def disarm(self) -> None:
        """Stop firing new faults (already-installed wire rules keep their
        own expiries)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    # -- trigger loop --------------------------------------------------------

    def _loop(self) -> None:
        pending = list(self.plan.faults)
        while pending and not self._stop.is_set():
            now_s = time.monotonic() - self._armed_at
            items = self._items_fn() if self._items_fn is not None else 0
            due = [f for f in pending if self._due(f, now_s, items)]
            for fault in due:
                pending.remove(fault)
                try:
                    self._fire(fault, now_s, items)
                except Exception:
                    pass  # chaos must never take the cluster down itself
            if pending:
                self._stop.wait(self.POLL_S)

    @staticmethod
    def _due(fault: Fault, now_s: float, items: int) -> bool:
        if fault.after_items is not None:
            return items >= fault.after_items
        return now_s >= fault.at_s

    def _fire(self, fault: Fault, now_s: float, items: int) -> None:
        expires = (None if fault.duration_s is None
                   else time.monotonic() + fault.duration_s)
        if fault.kind == "kill_node":
            if self._kill is not None:
                self._kill(fault.node)
        elif fault.kind == "partition":
            # Silence in both directions: the node looks dead to the host
            # and the host looks dead to the node.
            self.wire.install(_WireRule(fault, "drop", "recv", expires))
            self.wire.install(_WireRule(fault, "drop", "send", expires))
        elif fault.kind == "partition_peer":
            # Cut the node's *peer data plane* only — the host link stays
            # healthy, so the control plane sees a live node whose peer
            # edges fail, and senders must walk their fallback targets
            # (ultimately the host relay).  The seam is process-local, so
            # this is only effective under the in-process launcher; under
            # subprocess pools it is a no-op (documented in peer.py).
            from repro.cluster import peer as peer_mod

            peer_mod.partition_node(
                fault.node, fault.duration_s
                if fault.duration_s is not None else 1.0)
        elif fault.kind in ("drop", "stall_heartbeat"):
            self.wire.install(_WireRule(fault, "drop", "recv", expires))
        elif fault.kind in ("delay", "straggler"):
            self.wire.install(_WireRule(fault, "delay", "recv", expires))
        elif fault.kind == "duplicate":
            self.wire.install(_WireRule(fault, "duplicate", "recv", expires))
        elif fault.kind == "corrupt":
            if fault.count is None:
                fault = Fault(**{**vars(fault), "count": 1})
            self.wire.install(_WireRule(fault, "corrupt", "send", expires))
        record = {
            "kind": fault.kind,
            "node": fault.node,
            "at_s": round(now_s, 3),
            "at_item": items,
        }
        with self._lock:
            self.injected += 1
            self.fired.append(record)
        if self.telemetry is not None:
            self.telemetry.inc("faults_injected")
            self.telemetry.emit(
                "chaos_inject",
                fault=fault.kind,
                node=fault.node,
                at_item=items,
                duration_s=fault.duration_s,
                probability=fault.probability,
                delay_s=(fault.delay_s
                         if fault.kind in ("delay", "straggler") else None),
            )

    # -- telemetry sampler ---------------------------------------------------

    def sample(self) -> dict:
        """The ``chaos`` section of the metrics snapshot."""
        with self._lock:
            fired = list(self.fired)
            injected = self.injected
        return {
            "armed": self.armed,
            "faults_planned": len(self.plan.faults),
            "faults_injected": injected,
            "active_wire_rules": self.wire.active_count(),
            "fired": fired,
        }


def chaos_events(events: Iterable[dict]) -> list[dict]:
    """Filter a telemetry event stream down to the chaos/heal story
    (convenience for tests and benches asserting on /events)."""
    kinds = {"chaos_inject", "failure", "heal", "heal_failed", "respawn",
             "job_retry"}
    return [e for e in events if e.get("kind") in kinds]
