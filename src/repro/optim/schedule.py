"""Learning-rate schedules (warmup + cosine decay, the LM default)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step,
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_fraction: float = 0.1,
):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    progress = jnp.clip(
        (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = final_fraction + (1 - final_fraction) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress)
    )
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


def constant(step, *, peak_lr: float, **_kw):
    del step
    return jnp.float32(peak_lr)
