"""Wire format of the load and application networks.

Every message is one *frame*::

    +-------+---------+-------+-------+---------+----------+-----------+
    | magic | version | ftype | codec | channel | length   | payload   |
    | 4B    | 1B      | 1B    | 1B    | 1B      | 4B (!I)  | length B  |
    +-------+---------+-------+-------+---------+----------+-----------+

``ftype`` is the protocol event — the same alphabet as the CSP model in
``core.protocol`` plus the bootstrap events of paper §4 (Figure 1):
REGISTER/LOAD/HEARTBEAT ride the *load network* (channel 1, the paper's
"port 2000 channel 1"), WORK_REQUEST/WORK/RESULT/UT ride the *application
network* (channel 2).  ``UT`` is the paper's Universal Terminator made
visible on the wire.

Payload encoding is dual: **msgpack** (codec 0) for protocol-internal
messages built from plain JSON-ish data — cheap, language-neutral — and
**pickle** (codec 1, via cloudpickle when available) for user objects and
shipped code (the JCSP code-loading channel analogue of §4.1).  The encoder
picks msgpack only when the object round-trips *exactly* (no tuple→list
coercion of user data); anything else falls back to pickle.
"""

from __future__ import annotations

import enum
import io
import pickle
import socket
import struct
import threading
from dataclasses import dataclass
from typing import Any

try:
    import cloudpickle as _pickler
except ImportError:  # pragma: no cover - cloudpickle is in the image
    _pickler = pickle

try:
    import msgpack

    _HAVE_MSGPACK = True
except ImportError:  # pragma: no cover
    _HAVE_MSGPACK = False

MAGIC = b"CGPP"
VERSION = 1
LOAD_WIRE_CHANNEL = 1  # paper §6: the load network uses channel number 1
APP_WIRE_CHANNEL = 2  # the application network runs on a separate channel

# Guards against a corrupt length field consuming the heap.
MAX_FRAME_BYTES = 512 * 2**20

_HEADER = struct.Struct("!4sBBBBI")


class FrameType(enum.IntEnum):
    REGISTER = 1  # NL -> HNL: node id + capabilities (load network)
    LOAD = 2  # HNL -> NL: serialized deployment (code-loading channel)
    WORK_REQUEST = 3  # NL -> HNL: the nrfa client's demand signal (b!i.S)
    WORK = 4  # HNL -> NL: one work object (c!i.o)
    RESULT = 5  # NL -> HNL: one processed object (f!r)
    HEARTBEAT = 6  # NL -> HNL: liveness beacon (load network)
    UT = 7  # either direction: Universal Terminator / timing return


class _CodecId(enum.IntEnum):
    MSGPACK = 0
    PICKLE = 1


class UniversalTerminator:
    """The paper's UT object (§4, Listing 3 {3:21}), wire edition."""

    _instance: "UniversalTerminator | None" = None

    def __new__(cls) -> "UniversalTerminator":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "UT"


UT = UniversalTerminator()


@dataclass(frozen=True)
class Frame:
    ftype: FrameType
    payload: Any = None
    channel: int = APP_WIRE_CHANNEL


def _msgpack_safe(obj: Any) -> bool:
    """True iff msgpack round-trips ``obj`` exactly (no tuple coercion)."""
    if obj is None or isinstance(obj, (bool, str, bytes, float)):
        return True
    if isinstance(obj, int):
        return -(2**63) <= obj < 2**64  # msgpack int range; beyond -> pickle
    if isinstance(obj, list):
        return all(_msgpack_safe(v) for v in obj)
    if isinstance(obj, dict):
        return all(
            isinstance(k, str) and _msgpack_safe(v) for k, v in obj.items()
        )
    return False


def encode_payload(obj: Any) -> tuple[int, bytes]:
    if _HAVE_MSGPACK and _msgpack_safe(obj):
        return _CodecId.MSGPACK, msgpack.packb(obj, use_bin_type=True)
    return _CodecId.PICKLE, _pickler.dumps(obj)


def decode_payload(codec: int, raw: bytes) -> Any:
    if codec == _CodecId.MSGPACK:
        if not _HAVE_MSGPACK:  # pragma: no cover - symmetric environments
            raise RuntimeError("received msgpack frame but msgpack unavailable")
        return msgpack.unpackb(raw, raw=False)
    if codec == _CodecId.PICKLE:
        return pickle.loads(raw)
    raise ValueError(f"unknown payload codec {codec}")


def pack_frame(frame: Frame) -> bytes:
    codec, raw = encode_payload(frame.payload)
    if len(raw) > MAX_FRAME_BYTES:
        raise ValueError(f"frame payload too large: {len(raw)} bytes")
    header = _HEADER.pack(
        MAGIC, VERSION, int(frame.ftype), int(codec), frame.channel, len(raw)
    )
    return header + raw


def unpack_frame(buf: bytes) -> Frame:
    return read_frame(io.BytesIO(buf).read)


def _read_exactly(read, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = read(remaining)
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


def read_frame(read) -> Frame:
    """Read one frame from any ``read(n) -> bytes`` source (socket, buffer)."""
    header = _read_exactly(read, _HEADER.size)
    magic, version, ftype, codec, channel, length = _HEADER.unpack(header)
    if magic != MAGIC:
        raise ValueError(f"bad frame magic {magic!r}")
    if version != VERSION:
        raise ValueError(f"unsupported wire version {version}")
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {length} exceeds cap")
    raw = _read_exactly(read, length) if length else b""
    return Frame(FrameType(ftype), decode_payload(codec, raw), channel)


class FrameConnection:
    """A framed, thread-safe view of one TCP socket.

    Many threads may ``send`` (workers delivering results while the heartbeat
    thread beats); exactly one thread should ``recv`` — the reader owns frame
    routing (see :mod:`repro.cluster.netchannels`).
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self._closed = False
        # TCP_NODELAY: frames are small and latency-sensitive (demand signals).
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # pragma: no cover - non-TCP sockets in tests
            pass

    @property
    def peer(self) -> str:
        try:
            name = self.sock.getpeername()
        except OSError:
            return "<closed>"
        if isinstance(name, tuple) and len(name) >= 2:
            return f"{name[0]}:{name[1]}"
        return str(name) or "<unnamed>"  # AF_UNIX pairs have no address

    def send(self, frame: Frame) -> None:
        data = pack_frame(frame)
        with self._send_lock:
            self.sock.sendall(data)

    def recv(self) -> Frame:
        return read_frame(self.sock.recv)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def dumps_code(obj: Any) -> bytes:
    """Serialise shipped code (work functions, details) by value.

    cloudpickle captures closures and locally-defined functions; plain pickle
    (the fallback) requires them to be importable on the node — which the
    launcher guarantees by exporting the host's ``sys.path``.
    """
    return _pickler.dumps(obj)


def loads_code(raw: bytes) -> Any:
    return pickle.loads(raw)
